//! The model-worker thread and its dynamic batcher — the serving-side
//! heart of the coordinator (paper §4.2 made concrete: model evaluations
//! batch across concurrent streams even though each BB-ANS stream is
//! sequential).
//!
//! The PJRT handles are not `Send`, so ONE worker thread owns the engine
//! and all backends; callers talk to it through an MPSC queue. The worker
//! drains up to `max_jobs` requests inside a `batch_window`, then:
//!
//! * **encode**: all posterior parameters for all images of all jobs in
//!   the batch are computed in one chunked NN dispatch up front; then the
//!   per-stream ANS coding interleaves with *cross-stream* batched
//!   likelihood calls, image-step by image-step.
//! * **decode**: streams advance in lock-step — pop priors (per stream),
//!   one batched decoder call, pop pixels (per stream), one batched
//!   encoder call to return the bits — so S concurrent decodes cost
//!   ⌈S/B⌉ NN dispatches per image instead of S.
//!
//! ## The `Sync`-backend fan-out (ISSUE 5)
//!
//! The single-threaded worker is a *PJRT* constraint, not an
//! architectural one. When every backend is `Send + Sync` (the pure-Rust
//! `NativeVae`), [`ModelService::spawn_with_sync`] runs the same batching
//! loop with each lock-step phase **fanned out over a scoped worker
//! pool** ([`ServiceParams::fanout_workers`]):
//!
//! * NN dispatches split their rows over the pool
//!   ([`crate::model::encode_batch_sharded`] /
//!   [`crate::model::decode_batch_sharded`]) — bitwise safe by the
//!   batched-call row-independence contract;
//! * the per-stream ANS phases (pop posteriors, push pixels+priors, pop
//!   priors, push posteriors) run streams in parallel — each stream's
//!   coder state is independent, and results are stitched back in stream
//!   order, so the containers are byte-identical to the serial worker's
//!   (pinned by `sync_service_bytes_match_serial_service`);
//! * chunk-parallel (`BBC2`) and hierarchical (`BBC3`) containers decode
//!   over the pool (speculative first-image scheduling included) instead
//!   of sequentially inside the worker thread.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::mpsc;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use super::metrics::Metrics;
use crate::ans::Ans;
use crate::bbans::container::{
    Container, HierContainer, ParallelContainer, MAGIC_HIER, MAGIC_PARALLEL,
};
use crate::bbans::hierarchy::HierCodec;
use crate::bbans::{BbAnsConfig, CodecScratch, VaeCodec};
use crate::model::hierarchy::HierVae;
use crate::model::tensor::Matrix;
use crate::model::{
    vae::NativeVae, vae::PjrtVae, Backend, Likelihood, ModelMeta, PixelParams, PosteriorBatch,
};
use crate::runtime::{load_config, Engine};

/// Service tuning knobs.
#[derive(Debug, Clone)]
pub struct ServiceParams {
    /// Max jobs drained into one scheduling round.
    pub max_jobs: usize,
    /// How long to linger after the first job arrives, collecting more.
    pub batch_window: Duration,
    /// Default coding config for compression (decode uses the container's).
    pub bbans: BbAnsConfig,
    /// Worker threads the `Sync`-backend service variant fans lock-step
    /// phases out over (`0` = available parallelism). Ignored by the
    /// single-threaded (PJRT-constrained) worker.
    pub fanout_workers: usize,
}

impl Default for ServiceParams {
    fn default() -> Self {
        Self {
            max_jobs: 16,
            batch_window: Duration::from_millis(2),
            bbans: BbAnsConfig::default(),
            fanout_workers: 0,
        }
    }
}

/// A backend shareable across the fan-out pool.
pub type SharedBackend = Arc<dyn Backend + Send + Sync>;

/// What the model worker owns: thread-local backends behind the classic
/// single-threaded loop, or shared `Sync` backends plus a fan-out width.
enum BackendSet {
    Local(HashMap<String, Box<dyn Backend>>),
    Shared {
        map: HashMap<String, SharedBackend>,
        workers: usize,
    },
}

enum Job {
    Compress {
        model: String,
        images: Vec<Vec<u8>>,
        reply: mpsc::Sender<Result<Vec<u8>, String>>,
    },
    Decompress {
        container: Vec<u8>,
        reply: mpsc::Sender<Result<Vec<Vec<u8>>, String>>,
    },
    Stats {
        reply: mpsc::Sender<String>,
    },
    Shutdown,
}

/// Handle to the model-worker thread. Clonable; all clones feed the same
/// batcher queue.
pub struct ModelService {
    tx: mpsc::Sender<Job>,
    pub metrics: Arc<Metrics>,
    handle: Option<JoinHandle<()>>,
}

/// Cheap clonable submitter (no join handle).
#[derive(Clone)]
pub struct ServiceHandle {
    tx: mpsc::Sender<Job>,
    pub metrics: Arc<Metrics>,
}

impl ModelService {
    /// Spawn with the standard artifact-backed backends. The PJRT path
    /// keeps the single-threaded worker (its handles are thread-local);
    /// the native path upgrades to the `Sync`-backend fan-out service.
    pub fn spawn(artifact_dir: PathBuf, use_pjrt: bool, params: ServiceParams) -> ModelService {
        if use_pjrt {
            Self::spawn_with(params, move || pjrt_backends(&artifact_dir))
        } else {
            Self::spawn_with_sync(params, move || native_backends(&artifact_dir))
        }
    }

    /// Spawn with a custom backend factory (runs inside the worker thread
    /// — backends need not be `Send`).
    pub fn spawn_with<F>(params: ServiceParams, factory: F) -> ModelService
    where
        F: FnOnce() -> Result<HashMap<String, Box<dyn Backend>>> + Send + 'static,
    {
        Self::spawn_set(params, move || factory().map(BackendSet::Local))
    }

    /// Spawn the `Sync`-backend service variant: the same batching worker
    /// loop, with every lock-step phase fanned out over
    /// [`ServiceParams::fanout_workers`] scoped threads (module docs).
    /// Containers are byte-identical to the single-threaded worker's.
    pub fn spawn_with_sync<F>(params: ServiceParams, factory: F) -> ModelService
    where
        F: FnOnce() -> Result<HashMap<String, SharedBackend>> + Send + 'static,
    {
        let workers = if params.fanout_workers == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            params.fanout_workers
        };
        Self::spawn_set(params, move || {
            factory().map(|map| BackendSet::Shared { map, workers })
        })
    }

    fn spawn_set<F>(params: ServiceParams, factory: F) -> ModelService
    where
        F: FnOnce() -> Result<BackendSet> + Send + 'static,
    {
        let (tx, rx) = mpsc::channel::<Job>();
        let metrics = Arc::new(Metrics::new());
        let m2 = metrics.clone();
        let handle = std::thread::Builder::new()
            .name("bbans-model-worker".into())
            .spawn(move || worker_loop(rx, m2, params, factory))
            .expect("spawn model worker");
        ModelService {
            tx,
            metrics,
            handle: Some(handle),
        }
    }

    pub fn handle(&self) -> ServiceHandle {
        ServiceHandle {
            tx: self.tx.clone(),
            metrics: self.metrics.clone(),
        }
    }

    pub fn shutdown(mut self) {
        let _ = self.tx.send(Job::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for ModelService {
    fn drop(&mut self) {
        let _ = self.tx.send(Job::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl ServiceHandle {
    pub fn compress(&self, model: &str, images: Vec<Vec<u8>>) -> Result<Vec<u8>> {
        let t = Instant::now();
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Job::Compress {
                model: model.to_string(),
                images,
                reply,
            })
            .map_err(|_| anyhow!("service stopped"))?;
        let out = rx
            .recv()
            .map_err(|_| anyhow!("service dropped request"))?
            .map_err(|e| anyhow!("{e}"));
        self.metrics.request_latency.observe(t.elapsed());
        out
    }

    pub fn decompress(&self, container: Vec<u8>) -> Result<Vec<Vec<u8>>> {
        let t = Instant::now();
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Job::Decompress { container, reply })
            .map_err(|_| anyhow!("service stopped"))?;
        let out = rx
            .recv()
            .map_err(|_| anyhow!("service dropped request"))?
            .map_err(|e| anyhow!("{e}"));
        self.metrics.request_latency.observe(t.elapsed());
        out
    }

    pub fn stats_json(&self) -> Result<String> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Job::Stats { reply })
            .map_err(|_| anyhow!("service stopped"))?;
        rx.recv().map_err(|_| anyhow!("service dropped request"))
    }
}

/// Model names listed in the artifact config.
fn config_models(config: &crate::util::json::Json) -> Result<Vec<String>> {
    match config.get("models") {
        Some(crate::util::json::Json::Obj(m)) => Ok(m.keys().cloned().collect()),
        _ => bail!("model_config.json missing models"),
    }
}

/// Load one named native backend from the artifact bundle.
fn native_backend(
    artifact_dir: &Path,
    config: &crate::util::json::Json,
    name: &str,
) -> Result<NativeVae> {
    let m = config.get("models").unwrap().get(name).unwrap();
    let meta = ModelMeta {
        name: name.to_string(),
        pixels: config.req("pixels").map_err(|e| anyhow!("{e}"))?.as_usize().unwrap(),
        latent_dim: m.req("latent_dim").map_err(|e| anyhow!("{e}"))?.as_usize().unwrap(),
        hidden: m.req("hidden").map_err(|e| anyhow!("{e}"))?.as_usize().unwrap(),
        likelihood: Likelihood::parse(
            m.req("likelihood").map_err(|e| anyhow!("{e}"))?.as_str().unwrap(),
        )?,
        test_elbo_bpd: m
            .get("test_elbo_bpd")
            .and_then(|v| v.as_f64())
            .unwrap_or(f64::NAN),
    };
    let weights = artifact_dir.join(
        m.req("weights")
            .map_err(|e| anyhow!("{e}"))?
            .as_str()
            .unwrap(),
    );
    NativeVae::load(weights, meta)
}

/// PJRT backends from the artifact bundle — the single-threaded worker's
/// set (the handles are thread-local). Native backends go through
/// [`native_backends`] and the fan-out service instead.
fn pjrt_backends(artifact_dir: &Path) -> Result<HashMap<String, Box<dyn Backend>>> {
    let config = load_config(artifact_dir)?;
    let engine = Arc::new(Engine::cpu(artifact_dir)?);
    let mut map: HashMap<String, Box<dyn Backend>> = HashMap::new();
    for name in config_models(&config)? {
        map.insert(
            name.clone(),
            Box::new(PjrtVae::from_config(engine.clone(), &config, &name)?),
        );
    }
    Ok(map)
}

/// Native (`Send + Sync`) backends for the fan-out service variant.
fn native_backends(artifact_dir: &Path) -> Result<HashMap<String, SharedBackend>> {
    let config = load_config(artifact_dir)?;
    let mut map: HashMap<String, SharedBackend> = HashMap::new();
    for name in config_models(&config)? {
        map.insert(
            name.clone(),
            Arc::new(native_backend(artifact_dir, &config, &name)?),
        );
    }
    Ok(map)
}

// ------------------------------------------------------------ the worker

fn worker_loop<F>(
    rx: mpsc::Receiver<Job>,
    metrics: Arc<Metrics>,
    params: ServiceParams,
    factory: F,
) where
    F: FnOnce() -> Result<BackendSet>,
{
    let backends = match factory() {
        Ok(b) => b,
        Err(e) => {
            // Fail every request with the construction error.
            let msg = format!("backend init failed: {e:#}");
            eprintln!("[coordinator] {msg}");
            while let Ok(job) = rx.recv() {
                match job {
                    Job::Compress { reply, .. } => {
                        let _ = reply.send(Err(msg.clone()));
                    }
                    Job::Decompress { reply, .. } => {
                        let _ = reply.send(Err(msg.clone()));
                    }
                    Job::Stats { reply } => {
                        let _ = reply.send(metrics.snapshot_json().to_string());
                    }
                    Job::Shutdown => return,
                }
            }
            return;
        }
    };

    // Hierarchical backends rebuilt from BBC3 headers, memoized across
    // requests: the common case is many decodes of one published
    // container, and a rebuild re-derives every weight from the seed.
    let mut hier_cache: HashMap<String, HierVae> = HashMap::new();

    loop {
        // Block for the first job.
        let first = match rx.recv() {
            Ok(j) => j,
            Err(_) => return,
        };
        let mut jobs = vec![first];
        // Linger to fill the batch.
        let deadline = Instant::now() + params.batch_window;
        while jobs.len() < params.max_jobs {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(j) => jobs.push(j),
                Err(mpsc::RecvTimeoutError::Timeout) => break,
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
        }

        let t_batch = Instant::now();
        type CompressJob = (Vec<Vec<u8>>, mpsc::Sender<Result<Vec<u8>, String>>);
        let mut compress: HashMap<String, Vec<CompressJob>> = HashMap::new();
        let mut decompress: Vec<(Vec<u8>, mpsc::Sender<Result<Vec<Vec<u8>>, String>>)> = Vec::new();
        let mut saw_shutdown = false;
        for job in jobs {
            match job {
                Job::Compress {
                    model,
                    images,
                    reply,
                } => compress.entry(model).or_default().push((images, reply)),
                Job::Decompress { container, reply } => decompress.push((container, reply)),
                Job::Stats { reply } => {
                    let _ = reply.send(metrics.snapshot_json().to_string());
                }
                Job::Shutdown => saw_shutdown = true,
            }
        }

        for (model, group) in compress {
            Metrics::inc(&metrics.requests, group.len() as u64);
            match &backends {
                BackendSet::Local(map) => match map.get(&model) {
                    Some(b) => batched_encode(b.as_ref(), &params, &metrics, group),
                    None => reject_unknown_model(&metrics, &model, group),
                },
                BackendSet::Shared { map, workers } => match map.get(&model) {
                    Some(b) => batched_encode_fanout(&**b, *workers, &params, &metrics, group),
                    None => reject_unknown_model(&metrics, &model, group),
                },
            }
        }
        if !decompress.is_empty() {
            Metrics::inc(&metrics.requests, decompress.len() as u64);
            match &backends {
                BackendSet::Local(map) => {
                    batched_decode(map, &metrics, decompress, &mut hier_cache)
                }
                BackendSet::Shared { map, workers } => {
                    batched_decode_fanout(map, *workers, &metrics, decompress, &mut hier_cache)
                }
            }
        }
        metrics.batch_latency.observe(t_batch.elapsed());

        if saw_shutdown {
            return;
        }
    }
}

fn reject_unknown_model(
    metrics: &Metrics,
    model: &str,
    group: Vec<(Vec<Vec<u8>>, mpsc::Sender<Result<Vec<u8>, String>>)>,
) {
    for (_, reply) in group {
        Metrics::inc(&metrics.errors, 1);
        let _ = reply.send(Err(format!("unknown model '{model}'")));
    }
}

/// Cross-stream batched encode for one model.
///
/// KEEP IN SYNC with [`batched_encode_fanout`]: the two are the same
/// three-phase loop, but Rust cannot express "parallel only when
/// `B: Sync`" over one body — `dyn Backend` (PJRT) can never satisfy the
/// `Sync` bound the fanned phases need, even at `workers == 1` — so the
/// serial loop exists as a twin. Error handling, metrics accounting and
/// admission must match; the byte-identity test pins the happy path
/// (see ROADMAP for the unification idea).
fn batched_encode(
    backend: &dyn Backend,
    params: &ServiceParams,
    metrics: &Metrics,
    group: Vec<(Vec<Vec<u8>>, mpsc::Sender<Result<Vec<u8>, String>>)>,
) {
    let codec = match VaeCodec::new(backend, params.bbans) {
        Ok(c) => c,
        Err(e) => {
            for (_, reply) in group {
                let _ = reply.send(Err(format!("{e:#}")));
            }
            return;
        }
    };
    let meta = backend.meta();

    struct Stream {
        images: Vec<Vec<u8>>,
        /// First row of this stream in the shared posterior batch.
        base: usize,
        ans: Ans,
        next: usize,
        reply: mpsc::Sender<Result<Vec<u8>, String>>,
        failed: Option<String>,
        /// Per-stream coder buffers; `scratch.idx` carries the popped
        /// bucket indices across the batched generative-net dispatch.
        scratch: CodecScratch,
    }
    let mut streams: Vec<Stream> = Vec::with_capacity(group.len());

    // Phase 1: ONE batched recognition-net dispatch for every image of
    // every stream, packed into a single [rows, pixels] matrix.
    let mut posts: Option<PosteriorBatch> = None;
    {
        let mut data: Vec<f32> = Vec::new();
        let mut rows = 0usize;
        for (images, reply) in group {
            let failed = images
                .iter()
                .any(|i| i.len() != meta.pixels)
                .then(|| format!("image size != {}", meta.pixels));
            let base = rows;
            if failed.is_none() {
                for img in &images {
                    codec.scale_image_into(img, &mut data);
                }
                rows += images.len();
            }
            streams.push(Stream {
                images,
                base,
                ans: Ans::new(params.bbans.clean_seed),
                next: 0,
                reply,
                failed,
                scratch: CodecScratch::new(),
            });
        }
        if rows > 0 {
            Metrics::inc(&metrics.nn_calls, 1);
            Metrics::inc(&metrics.nn_items, rows as u64);
            match backend.encode_batch(&Matrix::new(rows, meta.pixels, data)) {
                Ok(p) => posts = Some(p),
                Err(e) => {
                    for s in &mut streams {
                        s.failed = Some(format!("posterior failed: {e:#}"));
                    }
                }
            }
        }
    }

    // Phase 2: lock-step image coding with one cross-stream batched
    // generative-net dispatch per image step.
    let mut ys_data: Vec<f32> = Vec::new();
    loop {
        let active: Vec<usize> = streams
            .iter()
            .enumerate()
            .filter(|(_, s)| s.failed.is_none() && s.next < s.images.len())
            .map(|(i, _)| i)
            .collect();
        if active.is_empty() {
            break;
        }
        let pb = posts.as_ref().expect("active streams imply a posterior batch");
        // (1) pop posteriors per stream; pack latents into one matrix.
        ys_data.clear();
        for &si in &active {
            let s = &mut streams[si];
            let (mu, sigma) = pb.row(s.base + s.next);
            let mut idx = std::mem::take(&mut s.scratch.idx);
            codec.pop_posterior_into(&mut s.ans, mu, sigma, &mut idx, &mut s.scratch.gauss);
            codec.latent_centres_into(&idx, &mut ys_data);
            s.scratch.idx = idx;
        }
        // (2) one batched generative-net dispatch for all active streams.
        let ym = Matrix::new(active.len(), meta.latent_dim, std::mem::take(&mut ys_data));
        Metrics::inc(&metrics.nn_calls, 1);
        Metrics::inc(&metrics.nn_items, active.len() as u64);
        match backend.decode_batch(&ym) {
            Ok(param_list) => {
                for (&si, pp) in active.iter().zip(param_list) {
                    let s = &mut streams[si];
                    let idx = std::mem::take(&mut s.scratch.idx);
                    codec.push_pixels_coder_scratch(
                        &mut s.ans,
                        &pp,
                        &s.images[s.next],
                        &mut s.scratch,
                    );
                    codec.push_prior(&mut s.ans, &idx);
                    s.scratch.idx = idx;
                    s.next += 1;
                    Metrics::inc(&metrics.images_encoded, 1);
                }
            }
            Err(e) => {
                for &si in &active {
                    streams[si].failed = Some(format!("likelihood failed: {e:#}"));
                }
            }
        }
        ys_data = ym.data;
    }

    // Phase 3: containers out.
    for s in streams {
        if let Some(msg) = s.failed {
            Metrics::inc(&metrics.errors, 1);
            let _ = s.reply.send(Err(msg));
            continue;
        }
        let container = Container {
            model: meta.name.clone(),
            backend_id: backend.backend_id(),
            cfg: params.bbans,
            num_images: s.images.len() as u32,
            pixels: meta.pixels as u32,
            message: s.ans.into_message(),
        };
        let bytes = container.to_bytes();
        Metrics::inc(&metrics.bytes_out, bytes.len() as u64);
        let _ = s.reply.send(Ok(bytes));
    }
}

/// Run `f` over every element of `items` on up to `workers` scoped
/// threads (contiguous slabs — the lock-step phases are short and even,
/// so stealing would buy nothing). Each element is mutated independently
/// and the caller reads results back in slice order, so thread scheduling
/// cannot reorder anything observable.
fn par_each<T: Send, F: Fn(&mut T) + Sync>(items: &mut [T], workers: usize, f: F) {
    let per = items.len().div_ceil(workers.max(1)).max(1);
    if workers <= 1 || items.len() <= 1 || per >= items.len() {
        for it in items {
            f(it);
        }
        return;
    }
    let f = &f;
    std::thread::scope(|scope| {
        for chunk in items.chunks_mut(per) {
            scope.spawn(move || {
                for it in chunk {
                    f(it);
                }
            });
        }
    });
}

/// [`batched_encode`] for `Sync` backends: the same three-phase loop with
/// the NN dispatches row-sharded over the pool and the per-stream ANS
/// phases run streams-in-parallel. Byte-identical containers — each
/// stream's coder work is untouched, the NN row contract guarantees the
/// sharded dispatches, and every cross-stream buffer is packed serially
/// in stream order. KEEP IN SYNC with [`batched_encode`] (see its docs
/// for why the twins cannot share one body).
fn batched_encode_fanout<B: Backend + Sync + ?Sized>(
    backend: &B,
    workers: usize,
    params: &ServiceParams,
    metrics: &Metrics,
    group: Vec<(Vec<Vec<u8>>, mpsc::Sender<Result<Vec<u8>, String>>)>,
) {
    let codec = match VaeCodec::new(backend, params.bbans) {
        Ok(c) => c,
        Err(e) => {
            for (_, reply) in group {
                let _ = reply.send(Err(format!("{e:#}")));
            }
            return;
        }
    };
    let meta = backend.meta();

    struct Stream {
        images: Vec<Vec<u8>>,
        /// First row of this stream in the shared posterior batch.
        base: usize,
        ans: Ans,
        next: usize,
        reply: mpsc::Sender<Result<Vec<u8>, String>>,
        failed: Option<String>,
        scratch: CodecScratch,
        /// This round's latent centres (packed serially after the phase).
        ys: Vec<f32>,
        /// This round's likelihood params (distributed serially before
        /// the push phase).
        pending: Option<PixelParams>,
    }
    let mut streams: Vec<Stream> = Vec::with_capacity(group.len());

    // Phase 1: one row-sharded recognition dispatch for every image of
    // every stream.
    let mut posts: Option<PosteriorBatch> = None;
    {
        let mut data: Vec<f32> = Vec::new();
        let mut rows = 0usize;
        for (images, reply) in group {
            let failed = images
                .iter()
                .any(|i| i.len() != meta.pixels)
                .then(|| format!("image size != {}", meta.pixels));
            let base = rows;
            if failed.is_none() {
                for img in &images {
                    codec.scale_image_into(img, &mut data);
                }
                rows += images.len();
            }
            streams.push(Stream {
                images,
                base,
                ans: Ans::new(params.bbans.clean_seed),
                next: 0,
                reply,
                failed,
                scratch: CodecScratch::new(),
                ys: Vec::new(),
                pending: None,
            });
        }
        if rows > 0 {
            Metrics::inc(&metrics.nn_calls, 1);
            Metrics::inc(&metrics.nn_items, rows as u64);
            match crate::model::encode_batch_sharded(
                backend,
                &Matrix::new(rows, meta.pixels, data),
                workers,
            ) {
                Ok(p) => posts = Some(p),
                Err(e) => {
                    for s in &mut streams {
                        s.failed = Some(format!("posterior failed: {e:#}"));
                    }
                }
            }
        }
    }

    // Phase 2: lock-step image coding; each round's per-stream ANS work
    // fans out over the pool, the generative dispatch row-shards.
    let mut ys_data: Vec<f32> = Vec::new();
    loop {
        let mut active: Vec<&mut Stream> = streams
            .iter_mut()
            .filter(|s| s.failed.is_none() && s.next < s.images.len())
            .collect();
        if active.is_empty() {
            break;
        }
        let pb = posts.as_ref().expect("active streams imply a posterior batch");
        // (1) pop posteriors per stream — parallel across streams.
        par_each(&mut active, workers, |s| {
            let (mu, sigma) = pb.row(s.base + s.next);
            let mut idx = std::mem::take(&mut s.scratch.idx);
            codec.pop_posterior_into(&mut s.ans, mu, sigma, &mut idx, &mut s.scratch.gauss);
            s.ys.clear();
            codec.latent_centres_into(&idx, &mut s.ys);
            s.scratch.idx = idx;
        });
        // Pack the latent matrix serially, in stream order.
        ys_data.clear();
        for s in active.iter() {
            ys_data.extend_from_slice(&s.ys);
        }
        // (2) one row-sharded generative dispatch for all active streams.
        let ym = Matrix::new(active.len(), meta.latent_dim, std::mem::take(&mut ys_data));
        Metrics::inc(&metrics.nn_calls, 1);
        Metrics::inc(&metrics.nn_items, active.len() as u64);
        match crate::model::decode_batch_sharded(backend, &ym, workers) {
            Ok(param_list) => {
                for (s, pp) in active.iter_mut().zip(param_list) {
                    s.pending = Some(pp);
                }
                // (3) push pixels + prior — parallel across streams.
                par_each(&mut active, workers, |s| {
                    let pp = s.pending.take().expect("params distributed above");
                    let idx = std::mem::take(&mut s.scratch.idx);
                    codec.push_pixels_coder_scratch(
                        &mut s.ans,
                        &pp,
                        &s.images[s.next],
                        &mut s.scratch,
                    );
                    codec.push_prior(&mut s.ans, &idx);
                    s.scratch.idx = idx;
                    s.next += 1;
                });
                Metrics::inc(&metrics.images_encoded, active.len() as u64);
            }
            Err(e) => {
                for s in active.iter_mut() {
                    s.failed = Some(format!("likelihood failed: {e:#}"));
                }
            }
        }
        ys_data = ym.data;
    }

    // Phase 3: containers out (serial, stream order).
    for s in streams {
        if let Some(msg) = s.failed {
            Metrics::inc(&metrics.errors, 1);
            let _ = s.reply.send(Err(msg));
            continue;
        }
        let container = Container {
            model: meta.name.clone(),
            backend_id: backend.backend_id(),
            cfg: params.bbans,
            num_images: s.images.len() as u32,
            pixels: meta.pixels as u32,
            message: s.ans.into_message(),
        };
        let bytes = container.to_bytes();
        Metrics::inc(&metrics.bytes_out, bytes.len() as u64);
        let _ = s.reply.send(Ok(bytes));
    }
}

/// [`batched_decode`] for `Sync` backends: BBC1 streams run the lock-step
/// loop with fanned phases and row-sharded dispatches; chunk-parallel
/// BBC2 and hierarchical BBC3 containers decode over the worker pool
/// (speculative first-image scheduling included) instead of sequentially.
/// KEEP IN SYNC with [`batched_decode`] (shared admission lives in
/// [`bbc2_codec`] / [`decode_hier_container`]).
fn batched_decode_fanout(
    backends: &HashMap<String, SharedBackend>,
    workers: usize,
    metrics: &Metrics,
    jobs: Vec<(Vec<u8>, mpsc::Sender<Result<Vec<Vec<u8>>, String>>)>,
    hier_cache: &mut HashMap<String, HierVae>,
) {
    type DecodeJob = (Container, mpsc::Sender<Result<Vec<Vec<u8>>, String>>);
    let mut by_model: HashMap<String, Vec<DecodeJob>> = HashMap::new();
    for (bytes, reply) in jobs {
        Metrics::inc(&metrics.bytes_in, bytes.len() as u64);
        if bytes.len() >= 4 && &bytes[0..4] == MAGIC_PARALLEL {
            decode_parallel_container_fanout(backends, workers, metrics, &bytes, reply);
            continue;
        }
        if bytes.len() >= 4 && &bytes[0..4] == MAGIC_HIER {
            decode_hier_container(Some(workers), metrics, &bytes, reply, hier_cache);
            continue;
        }
        match Container::from_bytes(&bytes) {
            Ok(c) => by_model.entry(c.model.clone()).or_default().push((c, reply)),
            Err(e) => {
                Metrics::inc(&metrics.errors, 1);
                let _ = reply.send(Err(format!("bad container: {e:#}")));
            }
        }
    }

    for (model, group) in by_model {
        let Some(backend) = backends.get(&model) else {
            for (_, reply) in group {
                Metrics::inc(&metrics.errors, 1);
                let _ = reply.send(Err(format!("unknown model '{model}'")));
            }
            continue;
        };
        let backend: &(dyn Backend + Send + Sync) = &**backend;

        struct Stream<'a> {
            ans: Ans,
            remaining: usize,
            out: Vec<Vec<u8>>,
            /// Built once at admission (each container carries its own
            /// config); `None` iff `failed` — constructing per phase
            /// would serialize the pool on the global bucket-table lock.
            codec: Option<VaeCodec<'a, dyn Backend + Send + Sync>>,
            reply: mpsc::Sender<Result<Vec<Vec<u8>>, String>>,
            failed: Option<String>,
            pending_idx: Vec<u32>,
            pending_img: Vec<u8>,
            scratch: CodecScratch,
            /// This round's latent centres / scaled pixels and params.
            ys: Vec<f32>,
            xs: Vec<f32>,
            pending: Option<PixelParams>,
            /// Row of this stream in the current round's batched outputs.
            row: usize,
        }
        let mut streams: Vec<Stream> = group
            .into_iter()
            .map(|(c, reply)| {
                let mut failed = if c.backend_id != backend.backend_id() {
                    Some(format!(
                        "container encoded with backend '{}', this service runs '{}'",
                        c.backend_id,
                        backend.backend_id()
                    ))
                } else {
                    None
                };
                let codec = match VaeCodec::new(backend, c.cfg) {
                    Ok(codec) => Some(codec),
                    Err(e) => {
                        if failed.is_none() {
                            failed = Some(format!("{e:#}"));
                        }
                        None
                    }
                };
                Stream {
                    ans: Ans::from_message(&c.message, c.cfg.clean_seed),
                    remaining: c.num_images as usize,
                    out: Vec::with_capacity(c.num_images as usize),
                    codec,
                    reply,
                    failed,
                    pending_idx: Vec::new(),
                    pending_img: Vec::new(),
                    scratch: CodecScratch::new(),
                    ys: Vec::new(),
                    xs: Vec::new(),
                    pending: None,
                    row: 0,
                }
            })
            .collect();

        let meta = backend.meta();
        let mut ys_data: Vec<f32> = Vec::new();
        let mut xs_data: Vec<f32> = Vec::new();
        loop {
            let mut active: Vec<&mut Stream> = streams
                .iter_mut()
                .filter(|s| s.failed.is_none() && s.remaining > 0)
                .collect();
            if active.is_empty() {
                break;
            }
            // (3⁻¹) pop priors — parallel across streams.
            par_each(&mut active, workers, |s| {
                let s = &mut **s;
                let codec = s.codec.as_ref().expect("validated at admission");
                codec.pop_prior_into(&mut s.ans, &mut s.pending_idx);
                s.ys.clear();
                codec.latent_centres_into(&s.pending_idx, &mut s.ys);
            });
            ys_data.clear();
            for s in active.iter() {
                ys_data.extend_from_slice(&s.ys);
            }
            // (2⁻¹) one row-sharded generative dispatch, pop pixels.
            let ym = Matrix::new(active.len(), meta.latent_dim, std::mem::take(&mut ys_data));
            Metrics::inc(&metrics.nn_calls, 1);
            Metrics::inc(&metrics.nn_items, active.len() as u64);
            let params_list = match crate::model::decode_batch_sharded(backend, &ym, workers) {
                Ok(p) => p,
                Err(e) => {
                    ys_data = ym.data;
                    for s in active.iter_mut() {
                        s.failed = Some(format!("likelihood failed: {e:#}"));
                    }
                    continue;
                }
            };
            ys_data = ym.data;
            for (s, pp) in active.iter_mut().zip(params_list) {
                s.pending = Some(pp);
            }
            par_each(&mut active, workers, |s| {
                let s = &mut **s;
                let pp = s.pending.take().expect("params distributed above");
                let codec = s.codec.as_ref().expect("validated at admission");
                s.pending_img = codec.pop_pixels_coder_scratch(&mut s.ans, &pp, &mut s.scratch);
                s.xs.clear();
                codec.scale_image_into(&s.pending_img, &mut s.xs);
            });
            xs_data.clear();
            for s in active.iter() {
                xs_data.extend_from_slice(&s.xs);
            }
            // (1⁻¹) one row-sharded recognition dispatch, push bits back.
            let xm = Matrix::new(active.len(), meta.pixels, std::mem::take(&mut xs_data));
            Metrics::inc(&metrics.nn_calls, 1);
            Metrics::inc(&metrics.nn_items, active.len() as u64);
            match crate::model::encode_batch_sharded(backend, &xm, workers) {
                Ok(posts) => {
                    for (r, s) in active.iter_mut().enumerate() {
                        s.row = r;
                    }
                    let posts = &posts;
                    par_each(&mut active, workers, |s| {
                        let s = &mut **s;
                        let codec = s.codec.as_ref().expect("validated at admission");
                        let (mu, sigma) = posts.row(s.row);
                        codec.push_posterior_scratch(
                            &mut s.ans,
                            mu,
                            sigma,
                            &s.pending_idx,
                            &mut s.scratch.gauss,
                        );
                        s.out.push(std::mem::take(&mut s.pending_img));
                        s.remaining -= 1;
                    });
                    Metrics::inc(&metrics.images_decoded, active.len() as u64);
                }
                Err(e) => {
                    for s in active.iter_mut() {
                        s.failed = Some(format!("posterior failed: {e:#}"));
                    }
                }
            }
            xs_data = xm.data;
        }

        for s in streams {
            if let Some(msg) = s.failed {
                Metrics::inc(&metrics.errors, 1);
                let _ = s.reply.send(Err(msg));
            } else {
                let mut out = s.out;
                out.reverse(); // stack order → original order
                let _ = s.reply.send(Ok(out));
            }
        }
    }
}

/// [`decode_parallel_container`] with the chunk pool: `Sync` backends
/// decode the independent BBC2 chains across `workers` threads
/// (speculative first-image scheduling included). Admission is the
/// shared [`bbc2_codec`] — identical accept/reject behaviour to the
/// single-threaded worker.
fn decode_parallel_container_fanout(
    backends: &HashMap<String, SharedBackend>,
    workers: usize,
    metrics: &Metrics,
    bytes: &[u8],
    reply: mpsc::Sender<Result<Vec<Vec<u8>>, String>>,
) {
    let fail = |msg: String| {
        Metrics::inc(&metrics.errors, 1);
        let _ = reply.send(Err(msg));
    };
    let pc = match ParallelContainer::from_bytes(bytes) {
        Ok(pc) => pc,
        Err(e) => return fail(format!("bad container: {e:#}")),
    };
    let Some(backend) = backends.get(&pc.model) else {
        return fail(format!("unknown model '{}'", pc.model));
    };
    let backend: &(dyn Backend + Send + Sync) = &**backend;
    let codec = match bbc2_codec(&pc, backend) {
        Ok(c) => c,
        Err(msg) => return fail(msg),
    };
    match pc.decode_with_workers(&codec, workers) {
        Ok(images) => {
            Metrics::inc(&metrics.images_decoded, images.len() as u64);
            let _ = reply.send(Ok(images));
        }
        Err(e) => fail(format!("parallel container decode failed: {e:#}")),
    }
}

/// Cross-stream batched decode (streams may use different models only if
/// those models share a backend entry; in practice we group by model).
///
/// KEEP IN SYNC with [`batched_decode_fanout`] — same twin situation as
/// [`batched_encode`] / [`batched_encode_fanout`].
fn batched_decode(
    backends: &HashMap<String, Box<dyn Backend>>,
    metrics: &Metrics,
    jobs: Vec<(Vec<u8>, mpsc::Sender<Result<Vec<Vec<u8>>, String>>)>,
    hier_cache: &mut HashMap<String, HierVae>,
) {
    // Parse containers and group by model. Chunk-parallel (BBC2)
    // containers have no cross-stream NN batching to exploit here — each
    // chunk is an independent chain — so they decode chunk-by-chunk
    // directly instead of joining the lock-step loop below.
    type DecodeJob = (Container, mpsc::Sender<Result<Vec<Vec<u8>>, String>>);
    let mut by_model: HashMap<String, Vec<DecodeJob>> = HashMap::new();
    for (bytes, reply) in jobs {
        Metrics::inc(&metrics.bytes_in, bytes.len() as u64);
        if bytes.len() >= 4 && &bytes[0..4] == MAGIC_PARALLEL {
            decode_parallel_container(backends, metrics, &bytes, reply);
            continue;
        }
        if bytes.len() >= 4 && &bytes[0..4] == MAGIC_HIER {
            decode_hier_container(None, metrics, &bytes, reply, hier_cache);
            continue;
        }
        match Container::from_bytes(&bytes) {
            Ok(c) => by_model.entry(c.model.clone()).or_default().push((c, reply)),
            Err(e) => {
                Metrics::inc(&metrics.errors, 1);
                let _ = reply.send(Err(format!("bad container: {e:#}")));
            }
        }
    }

    for (model, group) in by_model {
        let Some(backend) = backends.get(&model) else {
            for (_, reply) in group {
                Metrics::inc(&metrics.errors, 1);
                let _ = reply.send(Err(format!("unknown model '{model}'")));
            }
            continue;
        };
        let backend = backend.as_ref();

        struct Stream {
            ans: Ans,
            remaining: usize,
            out: Vec<Vec<u8>>,
            cfg: BbAnsConfig,
            reply: mpsc::Sender<Result<Vec<Vec<u8>>, String>>,
            failed: Option<String>,
            pending_idx: Vec<u32>,
            pending_img: Vec<u8>,
            scratch: CodecScratch,
        }
        let mut streams: Vec<Stream> = group
            .into_iter()
            .map(|(c, reply)| {
                let failed = if c.backend_id != backend.backend_id() {
                    Some(format!(
                        "container encoded with backend '{}', this service runs '{}'",
                        c.backend_id,
                        backend.backend_id()
                    ))
                } else {
                    None
                };
                Stream {
                    ans: Ans::from_message(&c.message, c.cfg.clean_seed),
                    remaining: c.num_images as usize,
                    out: Vec::with_capacity(c.num_images as usize),
                    cfg: c.cfg,
                    reply,
                    failed,
                    pending_idx: Vec::new(),
                    pending_img: Vec::new(),
                    scratch: CodecScratch::new(),
                }
            })
            .collect();

        let meta = backend.meta();
        let mut ys_data: Vec<f32> = Vec::new();
        let mut xs_data: Vec<f32> = Vec::new();
        loop {
            let active: Vec<usize> = streams
                .iter()
                .enumerate()
                .filter(|(_, s)| s.failed.is_none() && s.remaining > 0)
                .map(|(i, _)| i)
                .collect();
            if active.is_empty() {
                break;
            }
            // (3⁻¹) pop priors; pack latents into one matrix.
            ys_data.clear();
            for &si in &active {
                let s = &mut streams[si];
                let codec = match VaeCodec::new(backend, s.cfg) {
                    Ok(c) => c,
                    Err(e) => {
                        s.failed = Some(format!("{e:#}"));
                        continue;
                    }
                };
                codec.pop_prior_into(&mut s.ans, &mut s.pending_idx);
                codec.latent_centres_into(&s.pending_idx, &mut ys_data);
            }
            let still: Vec<usize> = active
                .iter()
                .copied()
                .filter(|&si| streams[si].failed.is_none())
                .collect();
            if still.is_empty() {
                continue;
            }
            // (2⁻¹) one batched generative-net dispatch, pop pixels.
            let ym = Matrix::new(still.len(), meta.latent_dim, std::mem::take(&mut ys_data));
            Metrics::inc(&metrics.nn_calls, 1);
            Metrics::inc(&metrics.nn_items, still.len() as u64);
            let params_list = match backend.decode_batch(&ym) {
                Ok(p) => p,
                Err(e) => {
                    ys_data = ym.data;
                    for &si in &still {
                        streams[si].failed = Some(format!("likelihood failed: {e:#}"));
                    }
                    continue;
                }
            };
            ys_data = ym.data;
            xs_data.clear();
            for (&si, pp) in still.iter().zip(params_list) {
                let s = &mut streams[si];
                let codec = VaeCodec::new(backend, s.cfg).expect("validated");
                s.pending_img = codec.pop_pixels_coder_scratch(&mut s.ans, &pp, &mut s.scratch);
                codec.scale_image_into(&s.pending_img, &mut xs_data);
            }
            // (1⁻¹) one batched recognition-net dispatch, push bits back.
            let xm = Matrix::new(still.len(), meta.pixels, std::mem::take(&mut xs_data));
            Metrics::inc(&metrics.nn_calls, 1);
            Metrics::inc(&metrics.nn_items, still.len() as u64);
            match backend.encode_batch(&xm) {
                Ok(posts) => {
                    for (r, &si) in still.iter().enumerate() {
                        let s = &mut streams[si];
                        let codec = VaeCodec::new(backend, s.cfg).expect("validated");
                        let (mu, sigma) = posts.row(r);
                        codec.push_posterior_scratch(
                            &mut s.ans,
                            mu,
                            sigma,
                            &s.pending_idx,
                            &mut s.scratch.gauss,
                        );
                        s.out.push(std::mem::take(&mut s.pending_img));
                        s.remaining -= 1;
                        Metrics::inc(&metrics.images_decoded, 1);
                    }
                }
                Err(e) => {
                    for &si in &still {
                        streams[si].failed = Some(format!("posterior failed: {e:#}"));
                    }
                }
            }
            xs_data = xm.data;
        }

        for s in streams {
            if let Some(msg) = s.failed {
                Metrics::inc(&metrics.errors, 1);
                let _ = s.reply.send(Err(msg));
            } else {
                let mut out = s.out;
                out.reverse(); // stack order → original order
                let _ = s.reply.send(Ok(out));
            }
        }
    }
}

/// Shared BBC2 admission: check the recorded backend id against the
/// hosted backend and build the container's codec — both service
/// variants must accept/reject exactly the same containers.
fn bbc2_codec<'a, B: Backend + ?Sized>(
    pc: &ParallelContainer,
    backend: &'a B,
) -> Result<VaeCodec<'a, B>, String> {
    if pc.backend_id != backend.backend_id() {
        return Err(format!(
            "container encoded with backend '{}', this service runs '{}'",
            pc.backend_id,
            backend.backend_id()
        ));
    }
    VaeCodec::new(backend, pc.cfg).map_err(|e| format!("{e:#}"))
}

/// Decode one chunk-parallel (BBC2) container against the owning model's
/// backend. `dyn Backend` is not `Sync`, so chunks decode sequentially
/// inside the worker thread; the parallel win belongs to `Sync` backends
/// via [`ParallelContainer::decode_with`] (the fan-out service's route).
fn decode_parallel_container(
    backends: &HashMap<String, Box<dyn Backend>>,
    metrics: &Metrics,
    bytes: &[u8],
    reply: mpsc::Sender<Result<Vec<Vec<u8>>, String>>,
) {
    let fail = |msg: String| {
        Metrics::inc(&metrics.errors, 1);
        let _ = reply.send(Err(msg));
    };
    let pc = match ParallelContainer::from_bytes(bytes) {
        Ok(pc) => pc,
        Err(e) => return fail(format!("bad container: {e:#}")),
    };
    let Some(backend) = backends.get(&pc.model) else {
        return fail(format!("unknown model '{}'", pc.model));
    };
    let codec = match bbc2_codec(&pc, backend.as_ref()) {
        Ok(c) => c,
        Err(msg) => return fail(msg),
    };
    match pc.decode_sequential(&codec) {
        Ok(images) => {
            Metrics::inc(&metrics.images_decoded, images.len() as u64);
            let _ = reply.send(Ok(images));
        }
        Err(e) => fail(format!("parallel container decode failed: {e:#}")),
    }
}

/// Decode one hierarchical (`BBC3`) container. The header is
/// self-describing, so the backend is rebuilt from it instead of looked up
/// in the model map. With `workers: None` (the single-threaded worker)
/// the container's chunks decode **in lock step**: every chain advances
/// one image per round with each round's net evaluations batched across
/// all chains. With `Some(workers)` (the `Sync`-backend fan-out service)
/// the independent chunks decode across the pool instead, speculative
/// first-image scheduling included — the rebuilt `HierVae` is `Sync`.
/// ONE function on purpose: the memoization key and its eviction bound
/// must stay identical across both service variants.
fn decode_hier_container(
    workers: Option<usize>,
    metrics: &Metrics,
    bytes: &[u8],
    reply: mpsc::Sender<Result<Vec<Vec<u8>>, String>>,
    cache: &mut HashMap<String, HierVae>,
) {
    let fail = |msg: String| {
        Metrics::inc(&metrics.errors, 1);
        let _ = reply.send(Err(msg));
    };
    let hc = match HierContainer::from_bytes(bytes) {
        Ok(hc) => hc,
        Err(e) => return fail(format!("bad container: {e:#}")),
    };
    // Memoization key covers the FULL header identity — backend_id alone
    // encodes only the seed, and a warm cache must accept/reject exactly
    // the same headers a cold one would (build_backend checks that
    // weight_seed and backend_id agree).
    let key = format!(
        "{}|{}|{}|{}|{}|{:?}",
        hc.backend_id,
        hc.weight_seed,
        hc.pixels,
        hc.hidden,
        hc.likelihood.tag(),
        hc.dims
    );
    if !cache.contains_key(&key) {
        let backend = match hc.build_backend() {
            Ok(b) => b,
            Err(e) => return fail(format!("{e:#}")),
        };
        if cache.len() >= 8 {
            cache.clear(); // crude bound; rebuilds are correct, just slow
        }
        cache.insert(key.clone(), backend);
    }
    let backend = cache.get(&key).expect("inserted above");
    let codec = match HierCodec::new(backend, hc.cfg, hc.schedule) {
        Ok(c) => c,
        Err(e) => return fail(format!("{e:#}")),
    };
    let decoded = match workers {
        None => hc.decode_lockstep(&codec),
        Some(w) => hc.decode_with_workers(&codec, w),
    };
    match decoded {
        Ok(images) => {
            Metrics::inc(&metrics.images_decoded, images.len() as u64);
            let _ = reply.send(Ok(images));
        }
        Err(e) => fail(format!("hierarchical container decode failed: {e:#}")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::vae::NativeVae;

    fn test_service(max_jobs: usize, window_ms: u64) -> ModelService {
        let params = ServiceParams {
            max_jobs,
            batch_window: Duration::from_millis(window_ms),
            ..Default::default()
        };
        ModelService::spawn_with(params, || {
            let meta = ModelMeta {
                name: "toy".into(),
                pixels: 36,
                latent_dim: 6,
                hidden: 10,
                likelihood: Likelihood::Bernoulli,
                test_elbo_bpd: f64::NAN,
            };
            let mut map: HashMap<String, Box<dyn Backend>> = HashMap::new();
            map.insert("toy".into(), Box::new(NativeVae::random(meta, 77)));
            Ok(map)
        })
    }

    fn sample_images(n: usize, seed: u64) -> Vec<Vec<u8>> {
        let mut rng = crate::util::rng::Rng::new(seed);
        (0..n)
            .map(|_| (0..36).map(|_| (rng.f64() < 0.3) as u8).collect())
            .collect()
    }

    /// The `Sync`-backend fan-out variant of [`test_service`]: same model
    /// (same meta, same seed → same weights), phases spread over `fanout`
    /// workers.
    fn test_service_sync(max_jobs: usize, window_ms: u64, fanout: usize) -> ModelService {
        let params = ServiceParams {
            max_jobs,
            batch_window: Duration::from_millis(window_ms),
            fanout_workers: fanout,
            ..Default::default()
        };
        ModelService::spawn_with_sync(params, || {
            let meta = ModelMeta {
                name: "toy".into(),
                pixels: 36,
                latent_dim: 6,
                hidden: 10,
                likelihood: Likelihood::Bernoulli,
                test_elbo_bpd: f64::NAN,
            };
            let mut map: HashMap<String, SharedBackend> = HashMap::new();
            map.insert("toy".into(), Arc::new(NativeVae::random(meta, 77)));
            Ok(map)
        })
    }

    /// The fan-out service must produce byte-identical containers to the
    /// single-threaded worker at every fan-out width, and each service
    /// must decode the other's output — the coordinator-level face of the
    /// ISSUE 5 determinism contract.
    #[test]
    fn sync_service_bytes_match_serial_service() {
        let serial = test_service(4, 1);
        let images = sample_images(9, 31);
        let reference = serial.handle().compress("toy", images.clone()).unwrap();
        for fanout in [1usize, 3] {
            let sync = test_service_sync(4, 1, fanout);
            let h = sync.handle();
            let bytes = h.compress("toy", images.clone()).unwrap();
            assert_eq!(bytes, reference, "fanout={fanout} changed container bytes");
            assert_eq!(h.decompress(reference.clone()).unwrap(), images);
            sync.shutdown();
        }
        assert_eq!(serial.handle().decompress(reference).unwrap(), images);
        serial.shutdown();
    }

    #[test]
    fn sync_service_concurrent_requests_roundtrip_and_batch() {
        let svc = test_service_sync(8, 30, 2);
        let h = svc.handle();
        let mut threads = Vec::new();
        for t in 0..6 {
            let h = h.clone();
            threads.push(std::thread::spawn(move || {
                let images = sample_images(5, 300 + t);
                let c = h.compress("toy", images.clone()).unwrap();
                let out = h.decompress(c).unwrap();
                assert_eq!(out, images);
            }));
        }
        for t in threads {
            t.join().unwrap();
        }
        let mbs = svc.metrics.mean_batch_size();
        assert!(mbs > 1.5, "expected cross-stream batching, got {mbs:.2}");
        svc.shutdown();
    }

    #[test]
    fn sync_service_decodes_chunked_and_hier_containers() {
        use crate::bbans::hierarchy::Schedule;
        use crate::model::hierarchy::{HierMeta, HierVae};
        // Offline BBC2 from the same toy model the service hosts.
        let meta = ModelMeta {
            name: "toy".into(),
            pixels: 36,
            latent_dim: 6,
            hidden: 10,
            likelihood: Likelihood::Bernoulli,
            test_elbo_bpd: f64::NAN,
        };
        let backend = NativeVae::random(meta, 77);
        let codec = VaeCodec::new(&backend, BbAnsConfig::default()).unwrap();
        let images = sample_images(9, 21);
        let pc = crate::bbans::container::ParallelContainer::encode_with(&codec, &images, 3)
            .unwrap();
        // Offline BBC3 (self-describing header).
        let hmeta = HierMeta {
            name: "hier2".into(),
            pixels: 36,
            dims: vec![6, 4],
            hidden: 10,
            likelihood: Likelihood::Bernoulli,
        };
        let hbackend = HierVae::random(hmeta, 99);
        let hcodec = HierCodec::new(&hbackend, BbAnsConfig::default(), Schedule::BitSwap).unwrap();
        let hc = HierContainer::encode_with_workers(&hcodec, &images, 3, 2).unwrap();

        let svc = test_service_sync(4, 1, 3);
        let h = svc.handle();
        assert_eq!(h.decompress(pc.to_bytes()).unwrap(), images);
        assert_eq!(h.decompress(hc.to_bytes()).unwrap(), images);
        // Wrong backend ids still rejected through the fan-out paths.
        let mut bad = pc;
        bad.backend_id = "pjrt-b16".into();
        assert!(h.decompress(bad.to_bytes()).is_err());
        let mut badh = hc;
        badh.backend_id = "hier-native-s1".into();
        assert!(h.decompress(badh.to_bytes()).is_err());
        svc.shutdown();
    }

    #[test]
    fn compress_decompress_roundtrip_through_service() {
        let svc = test_service(4, 1);
        let h = svc.handle();
        let images = sample_images(7, 1);
        let container = h.compress("toy", images.clone()).unwrap();
        let out = h.decompress(container).unwrap();
        assert_eq!(out, images);
        svc.shutdown();
    }

    #[test]
    fn concurrent_requests_get_batched() {
        let svc = test_service(8, 30);
        let h = svc.handle();
        let mut threads = Vec::new();
        for t in 0..6 {
            let h = h.clone();
            threads.push(std::thread::spawn(move || {
                let images = sample_images(5, 100 + t);
                let c = h.compress("toy", images.clone()).unwrap();
                let out = h.decompress(c).unwrap();
                assert_eq!(out, images);
            }));
        }
        for t in threads {
            t.join().unwrap();
        }
        // With 6 concurrent 5-image streams and a 30ms window, NN calls
        // must have been shared across streams.
        let mbs = svc.metrics.mean_batch_size();
        assert!(mbs > 1.5, "expected cross-stream batching, got {mbs:.2}");
        svc.shutdown();
    }

    #[test]
    fn unknown_model_and_bad_container_error_cleanly() {
        let svc = test_service(4, 1);
        let h = svc.handle();
        assert!(h.compress("nope", sample_images(1, 3)).is_err());
        assert!(h.decompress(vec![1, 2, 3]).is_err());
        let stats = h.stats_json().unwrap();
        assert!(stats.contains("errors"));
        svc.shutdown();
    }

    #[test]
    fn wrong_backend_container_rejected() {
        let svc = test_service(4, 1);
        let h = svc.handle();
        let images = sample_images(2, 9);
        let c = h.compress("toy", images).unwrap();
        let mut parsed = Container::from_bytes(&c).unwrap();
        parsed.backend_id = "pjrt-b16".into();
        assert!(h.decompress(parsed.to_bytes()).is_err());
        svc.shutdown();
    }

    #[test]
    fn chunk_parallel_container_decodes_through_service() {
        // A BBC2 container produced offline by the chunk-parallel encoder
        // must decode through the serving path. The test backend mirrors
        // test_service's factory (same meta, same seed → same weights).
        let meta = ModelMeta {
            name: "toy".into(),
            pixels: 36,
            latent_dim: 6,
            hidden: 10,
            likelihood: Likelihood::Bernoulli,
            test_elbo_bpd: f64::NAN,
        };
        let backend = NativeVae::random(meta, 77);
        let codec = VaeCodec::new(&backend, BbAnsConfig::default()).unwrap();
        let images = sample_images(9, 21);
        let pc = crate::bbans::container::ParallelContainer::encode_with(&codec, &images, 3)
            .unwrap();

        let svc = test_service(4, 1);
        let h = svc.handle();
        assert_eq!(h.decompress(pc.to_bytes()).unwrap(), images);

        // Wrong backend id still rejected for BBC2.
        let mut bad = pc;
        bad.backend_id = "pjrt-b16".into();
        assert!(h.decompress(bad.to_bytes()).is_err());
        svc.shutdown();
    }

    #[test]
    fn hier_container_decodes_through_service() {
        // A BBC3 container produced offline decodes through the serving
        // path via its self-describing header (lock-step across chunks).
        use crate::bbans::hierarchy::Schedule;
        use crate::model::hierarchy::{HierMeta, HierVae};
        let meta = HierMeta {
            name: "hier2".into(),
            pixels: 36,
            dims: vec![6, 4],
            hidden: 10,
            likelihood: Likelihood::Bernoulli,
        };
        let backend = HierVae::random(meta, 99);
        let codec = HierCodec::new(&backend, BbAnsConfig::default(), Schedule::BitSwap).unwrap();
        let images = sample_images(8, 21);
        let hc = HierContainer::encode_with_workers(&codec, &images, 3, 2).unwrap();

        let svc = test_service(4, 1);
        let h = svc.handle();
        assert_eq!(h.decompress(hc.to_bytes()).unwrap(), images);

        // A header whose backend id does not match its weight seed is
        // rejected instead of silently decoding with the wrong model.
        let mut bad = hc;
        bad.backend_id = "hier-native-s1".into();
        assert!(h.decompress(bad.to_bytes()).is_err());
        svc.shutdown();
    }

    #[test]
    fn wrong_image_size_rejected_per_stream() {
        let svc = test_service(4, 1);
        let h = svc.handle();
        let images = vec![vec![0u8; 35]];
        assert!(h.compress("toy", images).is_err());
        // Service still alive for good requests.
        let good = sample_images(2, 4);
        assert!(h.compress("toy", good).is_ok());
        svc.shutdown();
    }
}
