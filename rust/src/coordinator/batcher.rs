//! The model-worker thread and its dynamic batcher — the serving-side
//! heart of the coordinator (paper §4.2 made concrete: model evaluations
//! batch across concurrent streams even though each BB-ANS stream is
//! sequential).
//!
//! ## Admission
//!
//! Callers submit through a **bounded** queue
//! ([`ServiceParams::queue_cap`]) with `try_send` semantics: a full queue
//! rejects immediately ("service overloaded") instead of buffering
//! without limit, so backpressure surfaces at the client where it can be
//! acted on. The worker drains up to [`ServiceParams::max_jobs`] jobs per
//! round and flushes when the OLDEST admitted job has waited
//! [`ServiceParams::max_batch_delay`] — a deadline, not a sliding window,
//! so a trickle of arrivals cannot postpone the flush indefinitely.
//!
//! ## One loop, two executors
//!
//! Each round runs the lock-step batching loop:
//!
//! * **encode**: all posterior parameters for all images of all jobs in
//!   the batch are computed in one NN dispatch up front; then the
//!   per-stream ANS coding interleaves with *cross-stream* batched
//!   likelihood calls, image-step by image-step.
//! * **decode**: streams advance in lock-step — pop priors (per stream),
//!   one batched decoder call, pop pixels (per stream), one batched
//!   encoder call to return the bits — so S concurrent decodes cost
//!   ⌈S/B⌉ NN dispatches per image instead of S.
//!
//! The loop is written ONCE, generic over
//! [`super::executor::PhaseExecutor`]. Thread-bound (PJRT) backends run
//! it on a [`super::executor::SerialExecutor`] — everything inline on
//! the worker thread. `Send + Sync` backends (the pure-Rust `NativeVae`,
//! via [`ModelService::spawn_with_sync`]) run it on a
//! [`super::executor::PooledExecutor`]: NN dispatches row-sharded and
//! per-stream ANS phases slabbed over a **persistent** pool of
//! [`ServiceParams::fanout_workers`] threads, with a barrier between
//! phases. Containers are byte-identical across executors and worker
//! counts (the executor module states the contract; pinned by
//! `sync_service_bytes_match_serial_service`). Chunk-parallel (`BBC2`)
//! and hierarchical (`BBC3`) containers decode over the same pool.
//!
//! Hierarchical **encode** is reachable here too: a `CompressHier` job
//! carries a [`HierSpec`] (seed + shape instead of a hosted-model name),
//! is validated by the exact admission the BBC3 decode path uses, and
//! shares its rebuilt-backend memo cache.
//!
//! ## Fault containment
//!
//! Each round's work is split into **execution units** — one compress
//! group per model, one hierarchical job, one container decode — and
//! every unit runs under `catch_unwind`. A panicking backend dispatch or
//! codec step therefore fails only that unit's jobs (each reply gets
//! `internal panic: …` naming the payload) while the worker thread keeps
//! serving; a supervisor quarantines a unit key (model name /
//! rebuilt-header key) after [`ServiceParams::quarantine_after`]
//! consecutive panics so a poisoned model fast-fails instead of
//! re-panicking forever. Jobs whose TTL expired while queued are shed at
//! round formation, before any NN dispatch. Worker liveness is exported
//! through a drop guard on [`Metrics::worker_dead`] — it flips on EVERY
//! exit path, including an uncontained panic — so
//! [`ServiceHandle::is_alive`] and [`ServiceHandle::health_json`] need no
//! queue round-trip.

use std::collections::HashMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::{Path, PathBuf};
use std::sync::atomic::Ordering;
use std::sync::mpsc;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use super::executor::{PhaseExecutor, PhasePool, PooledExecutor, SerialExecutor};
use super::metrics::Metrics;
use super::protocol::HierSpec;
use crate::ans::Ans;
use crate::bbans::bbc4::{Bbc4Container, Bbc4Model, MAGIC_BBC4};
use crate::bbans::container::{
    Container, HierContainer, ParallelContainer, MAGIC_HIER, MAGIC_PARALLEL,
};
use crate::bbans::hierarchy::HierCodec;
use crate::bbans::{BbAnsConfig, CodecCore, CodecScratch, VaeCodec};
use crate::model::hierarchy::HierVae;
use crate::model::tensor::Matrix;
use crate::model::{
    vae::NativeVae, vae::PjrtVae, Backend, Likelihood, ModelMeta, PixelParams, PosteriorBatch,
};
use crate::runtime::{load_config, Engine};
use crate::util::json::Json;

/// Service tuning knobs.
#[derive(Debug, Clone)]
pub struct ServiceParams {
    /// Max jobs drained into one scheduling round.
    pub max_jobs: usize,
    /// Deadline for flushing a round, measured from the moment the
    /// OLDEST job in it was admitted (not from when the worker noticed
    /// it): a job never lingers longer than this plus the round running
    /// in front of it.
    pub max_batch_delay: Duration,
    /// Bound on jobs admitted but not yet drained into a round;
    /// submissions past it are rejected with "service overloaded"
    /// (backpressure, not unbounded buffering).
    pub queue_cap: usize,
    /// Default coding config for compression (decode uses the container's).
    pub bbans: BbAnsConfig,
    /// Worker threads the `Sync`-backend service variant keeps in its
    /// persistent phase pool (`0` = available parallelism). Ignored by
    /// the single-threaded (PJRT-constrained) worker.
    pub fanout_workers: usize,
    /// Default TTL applied to jobs submitted without an explicit one:
    /// jobs whose deadline passes while they queue are shed at round
    /// formation (replied "deadline exceeded") before any NN dispatch.
    /// `None` = jobs never expire while queued.
    pub default_ttl: Option<Duration>,
    /// Consecutive panicking execution units before their key (model
    /// name / rebuilt-header key) is quarantined: subsequent requests
    /// for it fast-fail instead of re-panicking. Clamped to >= 1.
    pub quarantine_after: u32,
}

impl Default for ServiceParams {
    fn default() -> Self {
        Self {
            max_jobs: 16,
            max_batch_delay: Duration::from_millis(2),
            queue_cap: 256,
            bbans: BbAnsConfig::default(),
            fanout_workers: 0,
            default_ttl: None,
            quarantine_after: 3,
        }
    }
}

/// A backend shareable across the phase pool.
pub type SharedBackend = Arc<dyn Backend + Send + Sync>;

/// What the model worker owns: thread-local backends driven serially, or
/// shared `Sync` backends plus the persistent pool that fans the
/// lock-step phases out.
enum BackendSet {
    Local(HashMap<String, Box<dyn Backend>>),
    Shared {
        map: HashMap<String, SharedBackend>,
        pool: PhasePool,
    },
}

type CompressReply = mpsc::Sender<Result<Vec<u8>, String>>;
type DecompressReply = mpsc::Sender<Result<Vec<Vec<u8>>, String>>;
/// `(images, reply, trace)` — `trace` is the request's trace id, `0` for
/// untraced jobs (the tracer ignores id 0 even when enabled).
type CompressJob = (Vec<Vec<u8>>, CompressReply, u64);
type DecompressJob = (Vec<u8>, DecompressReply, u64);
type HierJob = (HierSpec, Vec<Vec<u8>>, CompressReply, u64);

enum Job {
    Compress {
        model: String,
        images: Vec<Vec<u8>>,
        reply: CompressReply,
        trace: u64,
    },
    /// Hierarchical (Bit-Swap / BBC3) compression: the model is given by
    /// seed + shape in the spec rather than a hosted-model name.
    CompressHier {
        spec: HierSpec,
        images: Vec<Vec<u8>>,
        reply: CompressReply,
        trace: u64,
    },
    Decompress {
        container: Vec<u8>,
        reply: DecompressReply,
        trace: u64,
    },
    Stats {
        reply: mpsc::Sender<String>,
    },
    Shutdown,
}

impl Job {
    /// Trace id riding with this job (`0` = untraced).
    fn trace(&self) -> u64 {
        match self {
            Job::Compress { trace, .. }
            | Job::CompressHier { trace, .. }
            | Job::Decompress { trace, .. } => *trace,
            Job::Stats { .. } | Job::Shutdown => 0,
        }
    }
}

/// A job plus its admission timestamp — drives the flush deadline and
/// the queue-wait histogram — and its optional expiry deadline.
struct Queued {
    job: Job,
    at: Instant,
    /// Absolute deadline computed at admission from the job's TTL (or
    /// the service default). `None` = never expires while queued.
    deadline: Option<Instant>,
}

/// Handle to the model-worker thread. Clonable; all clones feed the same
/// bounded batcher queue.
pub struct ModelService {
    /// `None` once shutdown has run (so `Drop` cannot double-join).
    tx: Option<mpsc::SyncSender<Queued>>,
    pub metrics: Arc<Metrics>,
    handle: Option<JoinHandle<()>>,
    default_ttl: Option<Duration>,
}

/// Cheap clonable submitter (no join handle).
#[derive(Clone)]
pub struct ServiceHandle {
    tx: mpsc::SyncSender<Queued>,
    pub metrics: Arc<Metrics>,
    default_ttl: Option<Duration>,
}

/// How long [`ModelService::shutdown`] and `Drop` keep nudging a full
/// admission queue before falling back to the channel-drop path.
const SHUTDOWN_PATIENCE: Duration = Duration::from_secs(5);

impl ModelService {
    /// Spawn with the standard artifact-backed backends. The PJRT path
    /// keeps the single-threaded worker (its handles are thread-local);
    /// the native path upgrades to the `Sync`-backend fan-out service.
    pub fn spawn(artifact_dir: PathBuf, use_pjrt: bool, params: ServiceParams) -> ModelService {
        if use_pjrt {
            Self::spawn_with(params, move || pjrt_backends(&artifact_dir))
        } else {
            Self::spawn_with_sync(params, move || native_backends(&artifact_dir))
        }
    }

    /// Spawn with a custom backend factory (runs inside the worker thread
    /// — backends need not be `Send`).
    pub fn spawn_with<F>(params: ServiceParams, factory: F) -> ModelService
    where
        F: FnOnce() -> Result<HashMap<String, Box<dyn Backend>>> + Send + 'static,
    {
        Self::spawn_set(params, move || factory().map(BackendSet::Local))
    }

    /// Spawn the `Sync`-backend service variant: the same batching worker
    /// loop, with every lock-step phase fanned out over a persistent pool
    /// of [`ServiceParams::fanout_workers`] threads (module docs).
    /// Containers are byte-identical to the single-threaded worker's.
    pub fn spawn_with_sync<F>(params: ServiceParams, factory: F) -> ModelService
    where
        F: FnOnce() -> Result<HashMap<String, SharedBackend>> + Send + 'static,
    {
        let workers = if params.fanout_workers == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            params.fanout_workers
        };
        Self::spawn_set(params, move || {
            factory().map(|map| BackendSet::Shared {
                map,
                pool: PhasePool::new(workers),
            })
        })
    }

    fn spawn_set<F>(params: ServiceParams, factory: F) -> ModelService
    where
        F: FnOnce() -> Result<BackendSet> + Send + 'static,
    {
        let (tx, rx) = mpsc::sync_channel::<Queued>(params.queue_cap.max(1));
        let metrics = Arc::new(Metrics::new());
        let m2 = metrics.clone();
        let default_ttl = params.default_ttl;
        let handle = std::thread::Builder::new()
            .name("bbans-model-worker".into())
            .spawn(move || worker_loop(rx, m2, params, factory))
            .expect("spawn model worker");
        ModelService {
            tx: Some(tx),
            metrics,
            handle: Some(handle),
            default_ttl,
        }
    }

    pub fn handle(&self) -> ServiceHandle {
        ServiceHandle {
            tx: self.tx.as_ref().expect("service not shut down").clone(),
            metrics: self.metrics.clone(),
            default_ttl: self.default_ttl,
        }
    }

    pub fn shutdown(mut self) {
        self.shutdown_bounded(SHUTDOWN_PATIENCE);
    }

    /// Deadline-bounded shutdown: returns `true` if the worker joined
    /// within `patience`. On `false` the worker thread is detached — it
    /// still exits on its own once its queue drains to the dropped
    /// channel, but the caller stops waiting (a worker wedged in a long
    /// round must not wedge shutdown with it).
    pub fn shutdown_within(mut self, patience: Duration) -> bool {
        self.shutdown_bounded(patience)
    }

    fn shutdown_bounded(&mut self, patience: Duration) -> bool {
        let deadline = Instant::now() + patience;
        if let Some(tx) = self.tx.take() {
            // Bounded send: a queue wedged full must not block shutdown
            // forever. If the Shutdown job never fits, dropping `tx`
            // disconnects the channel once every handle clone is gone,
            // which the worker treats as shutdown too.
            loop {
                let q = Queued {
                    job: Job::Shutdown,
                    at: Instant::now(),
                    deadline: None,
                };
                match tx.try_send(q) {
                    Ok(()) | Err(mpsc::TrySendError::Disconnected(_)) => break,
                    Err(mpsc::TrySendError::Full(_)) => {
                        if Instant::now() >= deadline {
                            break;
                        }
                        std::thread::sleep(Duration::from_millis(1));
                    }
                }
            }
        }
        match self.handle.take() {
            None => true,
            Some(h) => loop {
                if h.is_finished() {
                    let _ = h.join();
                    return true;
                }
                if Instant::now() >= deadline {
                    // Detach: the worker exits on its own later; the
                    // liveness bit (drop guard) records when it does.
                    return false;
                }
                std::thread::sleep(Duration::from_millis(1));
            },
        }
    }
}

impl Drop for ModelService {
    fn drop(&mut self) {
        if self.tx.is_some() || self.handle.is_some() {
            self.shutdown_bounded(SHUTDOWN_PATIENCE);
        }
    }
}

impl ServiceHandle {
    /// Admit one job to the bounded queue without blocking. A full queue
    /// is the backpressure signal: the caller gets an immediate error
    /// instead of feeding a silently growing backlog. The job's expiry
    /// deadline is fixed here, at admission, from `ttl` (or the service
    /// default when `ttl` is `None`).
    fn submit(&self, job: Job, ttl: Option<Duration>) -> Result<()> {
        let deadline = ttl.or(self.default_ttl).map(|t| Instant::now() + t);
        match self.tx.try_send(Queued {
            job,
            at: Instant::now(),
            deadline,
        }) {
            Ok(()) => {
                Metrics::inc(&self.metrics.queue_depth, 1);
                Ok(())
            }
            Err(mpsc::TrySendError::Full(_)) => {
                Metrics::inc(&self.metrics.rejected, 1);
                bail!("service overloaded: admission queue full")
            }
            Err(mpsc::TrySendError::Disconnected(_)) => bail!("service stopped"),
        }
    }

    pub fn compress(&self, model: &str, images: Vec<Vec<u8>>) -> Result<Vec<u8>> {
        self.compress_with(model, images, None)
    }

    /// [`ServiceHandle::compress`] with a per-request TTL: if the job is
    /// still queued when the TTL elapses it is shed (never dispatched)
    /// and the reply is "deadline exceeded".
    pub fn compress_with(
        &self,
        model: &str,
        images: Vec<Vec<u8>>,
        ttl: Option<Duration>,
    ) -> Result<Vec<u8>> {
        self.compress_opts(model, images, ttl, 0)
    }

    /// [`ServiceHandle::compress_with`] plus a trace id: when nonzero
    /// (and the global tracer is enabled) the request's admission, queue
    /// wait, round, and phase spans are recorded under `trace`.
    pub fn compress_opts(
        &self,
        model: &str,
        images: Vec<Vec<u8>>,
        ttl: Option<Duration>,
        trace: u64,
    ) -> Result<Vec<u8>> {
        let t = Instant::now();
        let n = images.len() as u64;
        let (reply, rx) = mpsc::channel();
        let job = Job::Compress {
            model: model.to_string(),
            images,
            reply,
            trace,
        };
        let admitted = self.submit(job, ttl);
        crate::obs::tracer().record(trace, "admission", t, t.elapsed(), n);
        admitted?;
        let out = rx
            .recv()
            .map_err(|_| anyhow!("service dropped request"))?
            .map_err(|e| anyhow!("{e}"));
        self.metrics.request_latency.observe(t.elapsed());
        out
    }

    /// Hierarchical (Bit-Swap / BBC3) compression. The model is specified
    /// by seed + shape in `spec`; admission mirrors the BBC3 decode path
    /// (seed, parameter budget, backend-id agreement).
    pub fn compress_hier(&self, spec: HierSpec, images: Vec<Vec<u8>>) -> Result<Vec<u8>> {
        self.compress_hier_with(spec, images, None)
    }

    /// [`ServiceHandle::compress_hier`] with a per-request TTL.
    pub fn compress_hier_with(
        &self,
        spec: HierSpec,
        images: Vec<Vec<u8>>,
        ttl: Option<Duration>,
    ) -> Result<Vec<u8>> {
        self.compress_hier_opts(spec, images, ttl, 0)
    }

    /// [`ServiceHandle::compress_hier_with`] plus a trace id.
    pub fn compress_hier_opts(
        &self,
        spec: HierSpec,
        images: Vec<Vec<u8>>,
        ttl: Option<Duration>,
        trace: u64,
    ) -> Result<Vec<u8>> {
        let t = Instant::now();
        let n = images.len() as u64;
        let (reply, rx) = mpsc::channel();
        let job = Job::CompressHier {
            spec,
            images,
            reply,
            trace,
        };
        let admitted = self.submit(job, ttl);
        crate::obs::tracer().record(trace, "admission", t, t.elapsed(), n);
        admitted?;
        let out = rx
            .recv()
            .map_err(|_| anyhow!("service dropped request"))?
            .map_err(|e| anyhow!("{e}"));
        self.metrics.request_latency.observe(t.elapsed());
        out
    }

    pub fn decompress(&self, container: Vec<u8>) -> Result<Vec<Vec<u8>>> {
        self.decompress_with(container, None)
    }

    /// [`ServiceHandle::decompress`] with a per-request TTL.
    pub fn decompress_with(
        &self,
        container: Vec<u8>,
        ttl: Option<Duration>,
    ) -> Result<Vec<Vec<u8>>> {
        self.decompress_opts(container, ttl, 0)
    }

    /// [`ServiceHandle::decompress_with`] plus a trace id.
    pub fn decompress_opts(
        &self,
        container: Vec<u8>,
        ttl: Option<Duration>,
        trace: u64,
    ) -> Result<Vec<Vec<u8>>> {
        let t = Instant::now();
        let (reply, rx) = mpsc::channel();
        let admitted = self.submit(
            Job::Decompress {
                container,
                reply,
                trace,
            },
            ttl,
        );
        crate::obs::tracer().record(trace, "admission", t, t.elapsed(), 1);
        admitted?;
        let out = rx
            .recv()
            .map_err(|_| anyhow!("service dropped request"))?
            .map_err(|e| anyhow!("{e}"));
        self.metrics.request_latency.observe(t.elapsed());
        out
    }

    /// Metrics snapshot, served handle-side from the shared
    /// [`Metrics`] — NOT through the bounded admission queue, so the
    /// stats probe still answers when the queue is full or the worker is
    /// dead (observability must survive exactly the conditions it exists
    /// to diagnose).
    pub fn stats_json(&self) -> Result<String> {
        Ok(self.metrics.snapshot_json().to_string())
    }

    /// Legacy worker-side stats path: round-trips a `Job::Stats` through
    /// admission, so it shares the queue's fate. Kept one release for
    /// callers that used the round-trip as a liveness side-channel —
    /// probe [`ServiceHandle::health_json`] instead.
    pub fn stats_json_via_worker(&self) -> Result<String> {
        let (reply, rx) = mpsc::channel();
        self.submit(Job::Stats { reply }, None)?;
        rx.recv().map_err(|_| anyhow!("service dropped request"))
    }

    /// Whether the model-worker thread is still running. Every worker
    /// exit path — clean shutdown, channel drop, uncontained panic —
    /// flips the liveness bit on the shared metrics through a drop
    /// guard, so this needs no queue round-trip.
    pub fn is_alive(&self) -> bool {
        !self.metrics.worker_dead.load(Ordering::Relaxed)
    }

    /// Health snapshot for load-balancer probes: liveness, queue depth,
    /// quarantine set, and fault counters. Handle-side like
    /// [`ServiceHandle::stats_json`] — it must answer while the service
    /// is unhealthy.
    pub fn health_json(&self) -> String {
        let m = &self.metrics;
        Json::obj(vec![
            ("alive", Json::Bool(self.is_alive())),
            ("uptime_s", Json::Num(m.uptime().as_secs_f64())),
            (
                "version",
                Json::Str(env!("CARGO_PKG_VERSION").to_string()),
            ),
            (
                "kernel_id",
                Json::Str(crate::simd::kernel_name().to_string()),
            ),
            (
                "queue_depth",
                Json::Num(m.queue_depth.load(Ordering::Relaxed) as f64),
            ),
            (
                "panics",
                Json::Num(m.panics.load(Ordering::Relaxed) as f64),
            ),
            (
                "expired",
                Json::Num(m.expired.load(Ordering::Relaxed) as f64),
            ),
            (
                "rounds",
                Json::Num(m.rounds.load(Ordering::Relaxed) as f64),
            ),
            (
                "heartbeat",
                Json::Num(m.heartbeat.load(Ordering::Relaxed) as f64),
            ),
            (
                "quarantined",
                Json::Arr(m.quarantined_keys().into_iter().map(Json::Str).collect()),
            ),
        ])
        .to_string()
    }
}

/// Model names listed in the artifact config.
fn config_models(config: &crate::util::json::Json) -> Result<Vec<String>> {
    match config.get("models") {
        Some(crate::util::json::Json::Obj(m)) => Ok(m.keys().cloned().collect()),
        _ => bail!("model_config.json missing models"),
    }
}

/// Load one named native backend from the artifact bundle. Every config
/// error routes through `Result` naming the offending field — a
/// malformed `model_config.json` must reach callers as the worker's
/// "backend init failed" reply, never panic the worker at init.
fn native_backend(
    artifact_dir: &Path,
    config: &crate::util::json::Json,
    name: &str,
) -> Result<NativeVae> {
    let m = config
        .get("models")
        .ok_or_else(|| anyhow!("model_config.json missing 'models'"))?
        .get(name)
        .ok_or_else(|| anyhow!("model_config.json missing models.{name}"))?;
    let usize_field = |obj: &Json, what: &str, key: &str| -> Result<usize> {
        obj.req(key)
            .map_err(|e| anyhow!("{what}: {e}"))?
            .as_usize()
            .ok_or_else(|| anyhow!("{what}.{key} is not a non-negative integer"))
    };
    let str_field = |key: &str| -> Result<&str> {
        m.req(key)
            .map_err(|e| anyhow!("models.{name}: {e}"))?
            .as_str()
            .ok_or_else(|| anyhow!("models.{name}.{key} is not a string"))
    };
    let meta = ModelMeta {
        name: name.to_string(),
        pixels: usize_field(config, "model_config.json", "pixels")?,
        latent_dim: usize_field(m, &format!("models.{name}"), "latent_dim")?,
        hidden: usize_field(m, &format!("models.{name}"), "hidden")?,
        likelihood: Likelihood::parse(str_field("likelihood")?)?,
        test_elbo_bpd: m
            .get("test_elbo_bpd")
            .and_then(|v| v.as_f64())
            .unwrap_or(f64::NAN),
    };
    let weights = artifact_dir.join(str_field("weights")?);
    NativeVae::load(weights, meta)
}

/// PJRT backends from the artifact bundle — the single-threaded worker's
/// set (the handles are thread-local). Native backends go through
/// [`native_backends`] and the fan-out service instead.
fn pjrt_backends(artifact_dir: &Path) -> Result<HashMap<String, Box<dyn Backend>>> {
    let config = load_config(artifact_dir)?;
    let engine = Arc::new(Engine::cpu(artifact_dir)?);
    let mut map: HashMap<String, Box<dyn Backend>> = HashMap::new();
    for name in config_models(&config)? {
        map.insert(
            name.clone(),
            Box::new(PjrtVae::from_config(engine.clone(), &config, &name)?),
        );
    }
    Ok(map)
}

/// Native (`Send + Sync`) backends for the fan-out service variant.
fn native_backends(artifact_dir: &Path) -> Result<HashMap<String, SharedBackend>> {
    let config = load_config(artifact_dir)?;
    let mut map: HashMap<String, SharedBackend> = HashMap::new();
    for name in config_models(&config)? {
        map.insert(
            name.clone(),
            Arc::new(native_backend(artifact_dir, &config, &name)?),
        );
    }
    Ok(map)
}

// ------------------------------------------------------------ the worker

/// Flips [`Metrics::worker_dead`] when the worker thread unwinds or
/// returns — installed first thing in [`worker_loop`], so the liveness
/// bit is accurate on EVERY exit path, including an uncontained panic.
struct AliveGuard(Arc<Metrics>);

impl Drop for AliveGuard {
    fn drop(&mut self) {
        self.0.worker_dead.store(true, Ordering::Relaxed);
    }
}

/// Per-key consecutive-panic accounting. Lives on the worker thread; the
/// quarantine SET itself lives in [`Metrics`] so handle-side probes read
/// it without a queue round-trip.
struct Supervisor {
    consecutive: HashMap<String, u32>,
    after: u32,
}

impl Supervisor {
    fn new(after: u32) -> Self {
        Supervisor {
            consecutive: HashMap::new(),
            after: after.max(1),
        }
    }

    fn note_ok(&mut self, key: &str) {
        self.consecutive.remove(key);
    }

    fn note_panic(&mut self, metrics: &Metrics, key: &str) {
        let n = self.consecutive.entry(key.to_string()).or_insert(0);
        *n += 1;
        if *n >= self.after {
            metrics.quarantine(key);
            eprintln!("[coordinator] quarantined '{key}' after {n} consecutive panics");
        }
    }
}

/// Best-effort text out of a caught panic payload.
fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Settle one contained execution unit: on success clear the key's
/// panic streak; on panic reply `internal panic: …` to every job in the
/// unit (through reply clones taken before the unit consumed its jobs —
/// a late send to an already-answered caller is harmless) and feed the
/// supervisor.
fn settle_unit<T>(
    metrics: &Metrics,
    sup: &mut Supervisor,
    key: &str,
    run: std::thread::Result<()>,
    replies: &[mpsc::Sender<Result<T, String>>],
) {
    match run {
        Ok(()) => sup.note_ok(key),
        Err(payload) => {
            let msg = format!("internal panic: {}", panic_message(payload.as_ref()));
            eprintln!("[coordinator] contained panic in unit '{key}': {msg}");
            Metrics::inc(&metrics.panics, 1);
            for reply in replies {
                Metrics::inc(&metrics.errors, 1);
                let _ = reply.send(Err(msg.clone()));
            }
            sup.note_panic(metrics, key);
        }
    }
}

/// Quarantine key for hierarchical (seed + shape) models — shared
/// between the `CompressHier` path and every rebuilt-header decode path
/// (BBC3, BBC4-hier), so a header that panics the rebuild-and-decode
/// machinery quarantines the same key a compress spec for it would.
fn hier_quarantine_key(seed: u64, hidden: u32, lik_tag: u8, dims: &[u32]) -> String {
    format!("hier:s{seed}|h{hidden}|l{lik_tag}|{dims:?}")
}

fn worker_loop<F>(
    rx: mpsc::Receiver<Queued>,
    metrics: Arc<Metrics>,
    params: ServiceParams,
    factory: F,
) where
    F: FnOnce() -> Result<BackendSet>,
{
    let _alive = AliveGuard(metrics.clone());
    let backends = match factory() {
        Ok(b) => b,
        Err(e) => {
            // Fail every request with the construction error.
            let msg = format!("backend init failed: {e:#}");
            eprintln!("[coordinator] {msg}");
            while let Ok(Queued { job, .. }) = rx.recv() {
                match job {
                    Job::Compress { reply, .. } | Job::CompressHier { reply, .. } => {
                        Metrics::dec(&metrics.queue_depth, 1);
                        let _ = reply.send(Err(msg.clone()));
                    }
                    Job::Decompress { reply, .. } => {
                        Metrics::dec(&metrics.queue_depth, 1);
                        let _ = reply.send(Err(msg.clone()));
                    }
                    Job::Stats { reply } => {
                        Metrics::dec(&metrics.queue_depth, 1);
                        let _ = reply.send(metrics.snapshot_json().to_string());
                    }
                    Job::Shutdown => return,
                }
            }
            return;
        }
    };

    // Hierarchical backends rebuilt from BBC3 headers (or CompressHier
    // specs), memoized across requests: the common case is many requests
    // against one published model, and a rebuild re-derives every weight
    // from the seed.
    let mut hier_cache: HashMap<String, HierVae> = HashMap::new();
    let mut supervisor = Supervisor::new(params.quarantine_after);

    loop {
        // Block for the first job.
        let first = match rx.recv() {
            Ok(q) => q,
            Err(_) => return,
        };
        Metrics::inc(&metrics.heartbeat, 1);
        // The flush deadline is anchored to the OLDEST job's ADMISSION
        // time: queue time spent waiting behind the previous round counts
        // against the linger budget, so under load rounds flush
        // immediately instead of lingering per round.
        let deadline = first.at + params.max_batch_delay;
        let mut jobs = vec![first];
        while jobs.len() < params.max_jobs {
            let now = Instant::now();
            if now >= deadline {
                // Past the deadline: take whatever is already queued,
                // never wait for more.
                match rx.try_recv() {
                    Ok(q) => jobs.push(q),
                    Err(_) => break,
                }
            } else {
                match rx.recv_timeout(deadline - now) {
                    Ok(q) => jobs.push(q),
                    Err(mpsc::RecvTimeoutError::Timeout) => continue,
                    Err(mpsc::RecvTimeoutError::Disconnected) => break,
                }
            }
        }

        Metrics::inc(&metrics.rounds, 1);
        let tr = crate::obs::tracer();
        let t_batch = Instant::now();
        let mut compress: HashMap<String, Vec<CompressJob>> = HashMap::new();
        let mut hier: Vec<HierJob> = Vec::new();
        let mut decompress: Vec<DecompressJob> = Vec::new();
        // Trace ids that made it into this round (for the round span).
        let mut traced: Vec<u64> = Vec::new();
        let mut saw_shutdown = false;
        for Queued { job, at, deadline } in jobs {
            if matches!(job, Job::Shutdown) {
                saw_shutdown = true;
                continue;
            }
            Metrics::dec(&metrics.queue_depth, 1);
            metrics.queue_wait.observe(at.elapsed());
            tr.record(job.trace(), "queue", at, at.elapsed(), 1);
            // Shed expired jobs HERE, at round formation — before the
            // round spends a single NN dispatch on work whose caller
            // already gave up.
            if deadline.is_some_and(|d| Instant::now() >= d) {
                Metrics::inc(&metrics.expired, 1);
                let msg = format!(
                    "deadline exceeded: job expired after {}ms in queue",
                    at.elapsed().as_millis()
                );
                match job {
                    Job::Compress { reply, .. } | Job::CompressHier { reply, .. } => {
                        let _ = reply.send(Err(msg));
                    }
                    Job::Decompress { reply, .. } => {
                        let _ = reply.send(Err(msg));
                    }
                    // Stats carries no TTL-sensitive work; answer anyway.
                    Job::Stats { reply } => {
                        let _ = reply.send(metrics.snapshot_json().to_string());
                    }
                    Job::Shutdown => unreachable!("filtered above"),
                }
                continue;
            }
            match job {
                Job::Compress {
                    model,
                    images,
                    reply,
                    trace,
                } => {
                    if trace != 0 {
                        traced.push(trace);
                    }
                    compress.entry(model).or_default().push((images, reply, trace));
                }
                Job::CompressHier {
                    spec,
                    images,
                    reply,
                    trace,
                } => {
                    if trace != 0 {
                        traced.push(trace);
                    }
                    hier.push((spec, images, reply, trace));
                }
                Job::Decompress {
                    container,
                    reply,
                    trace,
                } => {
                    if trace != 0 {
                        traced.push(trace);
                    }
                    decompress.push((container, reply, trace));
                }
                Job::Stats { reply } => {
                    let _ = reply.send(metrics.snapshot_json().to_string());
                }
                Job::Shutdown => unreachable!("filtered above"),
            }
        }

        // Each unit below runs under `catch_unwind`: a panic fails only
        // that unit's jobs and feeds the supervisor; the loop continues.
        for (model, group) in compress {
            Metrics::inc(&metrics.requests, group.len() as u64);
            if metrics.is_quarantined(&model) {
                for (_, reply, _) in group {
                    Metrics::inc(&metrics.errors, 1);
                    let msg = format!("model '{model}' is quarantined after repeated panics");
                    let _ = reply.send(Err(msg));
                }
                continue;
            }
            let replies: Vec<CompressReply> = group.iter().map(|(_, r, _)| r.clone()).collect();
            let run = catch_unwind(AssertUnwindSafe(|| {
                encode_group(&backends, &params, &metrics, &model, group);
            }));
            settle_unit(&metrics, &mut supervisor, &model, run, &replies);
        }
        if !hier.is_empty() {
            Metrics::inc(&metrics.requests, hier.len() as u64);
            for (spec, images, reply, trace) in hier {
                let key = hier_quarantine_key(
                    spec.seed,
                    spec.hidden,
                    spec.likelihood.tag(),
                    &spec.dims,
                );
                if metrics.is_quarantined(&key) {
                    Metrics::inc(&metrics.errors, 1);
                    let msg = format!("'{key}' is quarantined after repeated panics");
                    let _ = reply.send(Err(msg));
                    continue;
                }
                let n_images = images.len() as u64;
                let replies = [reply.clone()];
                let t_unit = Instant::now();
                let run = catch_unwind(AssertUnwindSafe(|| {
                    compress_hier_job(
                        &backends,
                        &params,
                        &metrics,
                        (spec, images, reply, trace),
                        &mut hier_cache,
                    );
                }));
                tr.record(trace, "exec", t_unit, t_unit.elapsed(), n_images);
                settle_unit(&metrics, &mut supervisor, &key, run, &replies);
            }
        }
        if !decompress.is_empty() {
            Metrics::inc(&metrics.requests, decompress.len() as u64);
            decode_jobs(
                &backends,
                &metrics,
                &mut supervisor,
                decompress,
                &mut hier_cache,
            );
        }
        metrics.batch_latency.observe(t_batch.elapsed());
        // Round span for every traced job, then drain the worker
        // thread's buffered spans into the global ring — the ring is
        // what `TraceReq` snapshots, so a round's spans are visible as
        // soon as its replies are.
        for &id in &traced {
            tr.record(id, "round", t_batch, t_batch.elapsed(), 1);
        }
        tr.flush();

        if saw_shutdown {
            return;
        }
    }
}

fn reject_unknown_model(metrics: &Metrics, model: &str, group: Vec<CompressJob>) {
    for (_, reply) in group {
        Metrics::inc(&metrics.errors, 1);
        let _ = reply.send(Err(format!("unknown model '{model}'")));
    }
}

/// Route one model's compress group to the right executor over the
/// unified [`batched_encode`] loop.
fn encode_group(
    backends: &BackendSet,
    params: &ServiceParams,
    metrics: &Metrics,
    model: &str,
    group: Vec<CompressJob>,
) {
    match backends {
        BackendSet::Local(map) => match map.get(model) {
            Some(b) => {
                let id = b.backend_id();
                let exec = SerialExecutor {
                    backend: b.as_ref(),
                };
                batched_encode(&exec, b.meta(), &id, params, metrics, group);
            }
            None => reject_unknown_model(metrics, model, group),
        },
        BackendSet::Shared { map, pool } => match map.get(model) {
            Some(b) => {
                let backend: &(dyn Backend + Send + Sync) = &**b;
                let id = backend.backend_id();
                let exec = PooledExecutor { backend, pool };
                batched_encode(&exec, backend.meta(), &id, params, metrics, group);
            }
            None => reject_unknown_model(metrics, model, group),
        },
    }
}

/// Cross-stream batched encode for one model — ONE loop for both service
/// variants, parameterized by the executor. Byte-identity across
/// executors and worker counts holds because each stream's coder work is
/// per-stream state only, the NN dispatches are row-independent, and
/// every cross-stream buffer is packed serially in stream order.
fn batched_encode<E: PhaseExecutor>(
    exec: &E,
    meta: &ModelMeta,
    backend_id: &str,
    params: &ServiceParams,
    metrics: &Metrics,
    group: Vec<CompressJob>,
) {
    let core = match CodecCore::new(meta.clone(), params.bbans) {
        Ok(c) => c,
        Err(e) => {
            for (_, reply, _) in group {
                let _ = reply.send(Err(format!("{e:#}")));
            }
            return;
        }
    };
    let core = &core;

    struct Stream {
        images: Vec<Vec<u8>>,
        /// First row of this stream in the shared posterior batch.
        base: usize,
        ans: Ans,
        next: usize,
        reply: CompressReply,
        /// Request trace id (`0` = untraced).
        trace: u64,
        failed: Option<String>,
        /// Per-stream coder buffers; `scratch.idx` carries the popped
        /// bucket indices across the batched generative-net dispatch.
        scratch: CodecScratch,
        /// This round's latent centres (packed serially after the phase).
        ys: Vec<f32>,
        /// This round's likelihood params (distributed serially before
        /// the push phase).
        pending: Option<PixelParams>,
    }
    let mut streams: Vec<Stream> = Vec::with_capacity(group.len());
    // Per-unit phase time, attributed to every traced stream in the
    // unit (phases are shared across streams by construction).
    let unit_start = Instant::now();
    let mut nn_acc = Duration::ZERO;
    let mut ans_acc = Duration::ZERO;

    // Phase 1: ONE batched recognition-net dispatch for every image of
    // every stream, packed into a single [rows, pixels] matrix.
    let mut posts: Option<PosteriorBatch> = None;
    {
        let mut data: Vec<f32> = Vec::new();
        let mut rows = 0usize;
        for (images, reply, trace) in group {
            let failed = images
                .iter()
                .any(|i| i.len() != meta.pixels)
                .then(|| format!("image size != {}", meta.pixels));
            let base = rows;
            if failed.is_none() {
                for img in &images {
                    core.scale_image_into(img, &mut data);
                }
                rows += images.len();
            }
            streams.push(Stream {
                images,
                base,
                ans: Ans::new(params.bbans.clean_seed),
                next: 0,
                reply,
                trace,
                failed,
                scratch: CodecScratch::new(),
                ys: Vec::new(),
                pending: None,
            });
        }
        if rows > 0 {
            Metrics::inc(&metrics.nn_calls, 1);
            Metrics::inc(&metrics.nn_items, rows as u64);
            let t = Instant::now();
            let r = exec.nn_posterior(&Matrix::new(rows, meta.pixels, data));
            nn_acc += t.elapsed();
            metrics.phase_nn.observe(t.elapsed());
            match r {
                Ok(p) => posts = Some(p),
                Err(e) => {
                    for s in &mut streams {
                        s.failed = Some(format!("posterior failed: {e:#}"));
                    }
                }
            }
        }
    }

    // Phase 2: lock-step image coding with one cross-stream batched
    // generative-net dispatch per image step.
    let mut ys_data: Vec<f32> = Vec::new();
    loop {
        let mut active: Vec<&mut Stream> = streams
            .iter_mut()
            .filter(|s| s.failed.is_none() && s.next < s.images.len())
            .collect();
        if active.is_empty() {
            break;
        }
        let pb = posts.as_ref().expect("active streams imply a posterior batch");
        // (1) pop posteriors per stream — across the executor's lanes.
        let t = Instant::now();
        exec.each_stream(&mut active, |s| {
            let s = &mut **s;
            let (mu, sigma) = pb.row(s.base + s.next);
            let mut idx = std::mem::take(&mut s.scratch.idx);
            core.pop_posterior_into(&mut s.ans, mu, sigma, &mut idx, &mut s.scratch.gauss);
            s.ys.clear();
            core.latent_centres_into(&idx, &mut s.ys);
            s.scratch.idx = idx;
        });
        ans_acc += t.elapsed();
        metrics.phase_ans.observe(t.elapsed());
        // Pack the latent matrix serially, in stream order.
        ys_data.clear();
        for s in active.iter() {
            ys_data.extend_from_slice(&s.ys);
        }
        // (2) one batched generative-net dispatch for all active streams.
        let ym = Matrix::new(active.len(), meta.latent_dim, std::mem::take(&mut ys_data));
        Metrics::inc(&metrics.nn_calls, 1);
        Metrics::inc(&metrics.nn_items, active.len() as u64);
        let t = Instant::now();
        let r = exec.nn_likelihood(&ym);
        nn_acc += t.elapsed();
        metrics.phase_nn.observe(t.elapsed());
        match r {
            Ok(param_list) => {
                for (s, pp) in active.iter_mut().zip(param_list) {
                    s.pending = Some(pp);
                }
                // (3) push pixels + prior — across the executor's lanes.
                let t = Instant::now();
                exec.each_stream(&mut active, |s| {
                    let s = &mut **s;
                    let pp = s.pending.take().expect("params distributed above");
                    let idx = std::mem::take(&mut s.scratch.idx);
                    core.push_pixels_coder_scratch(
                        &mut s.ans,
                        &pp,
                        &s.images[s.next],
                        &mut s.scratch,
                    );
                    core.push_prior(&mut s.ans, &idx);
                    s.scratch.idx = idx;
                    s.next += 1;
                });
                ans_acc += t.elapsed();
                metrics.phase_ans.observe(t.elapsed());
                Metrics::inc(&metrics.images_encoded, active.len() as u64);
            }
            Err(e) => {
                for s in active.iter_mut() {
                    s.failed = Some(format!("likelihood failed: {e:#}"));
                }
            }
        }
        ys_data = ym.data;
    }

    // Phase 3: containers out (serial, stream order). Traced streams get
    // the unit's accumulated NN / ANS phase time (shared across streams
    // — the phases batch cross-stream by design).
    let tr = crate::obs::tracer();
    for s in streams {
        if s.trace != 0 {
            let n = s.images.len() as u64;
            tr.record(s.trace, "nn", unit_start, nn_acc, n);
            tr.record(s.trace, "ans", unit_start, ans_acc, n);
        }
        if let Some(msg) = s.failed {
            Metrics::inc(&metrics.errors, 1);
            let _ = s.reply.send(Err(msg));
            continue;
        }
        let container = Container {
            model: meta.name.clone(),
            backend_id: backend_id.to_string(),
            cfg: params.bbans,
            num_images: s.images.len() as u32,
            pixels: meta.pixels as u32,
            message: s.ans.into_message(),
        };
        let bytes = container.to_bytes();
        Metrics::inc(&metrics.bytes_out, bytes.len() as u64);
        let _ = s.reply.send(Ok(bytes));
    }
}

/// Sniff and route one round's decompress jobs: BBC2/BBC3/BBC4
/// containers go to their dedicated decoders (over the phase pool when
/// the backends are `Sync`); plain BBC1 containers group by model and
/// run the unified lock-step [`batched_decode`] loop on the matching
/// executor. Containers are parsed FIRST, outside any unwind barrier
/// (parsing is panic-free; pinned by the fault-injection fuzz
/// campaigns), so every decode unit has its quarantine key before it
/// runs; each unit then executes under `catch_unwind`.
fn decode_jobs(
    backends: &BackendSet,
    metrics: &Metrics,
    sup: &mut Supervisor,
    jobs: Vec<DecompressJob>,
    hier_cache: &mut HashMap<String, HierVae>,
) {
    type GroupJob = (Container, DecompressReply, u64);
    enum Parsed {
        Bbc2(ParallelContainer, DecompressReply),
        Bbc3(HierContainer, DecompressReply),
        Bbc4(Bbc4Container, DecompressReply),
    }
    impl Parsed {
        fn reply(&self) -> &DecompressReply {
            match self {
                Parsed::Bbc2(_, r) | Parsed::Bbc3(_, r) | Parsed::Bbc4(_, r) => r,
            }
        }
    }
    let fail = |reply: DecompressReply, msg: String| {
        Metrics::inc(&metrics.errors, 1);
        let _ = reply.send(Err(msg));
    };
    let hier_key = |hc: &HierContainer| {
        hier_quarantine_key(hc.weight_seed, hc.hidden, hc.likelihood.tag(), &hc.dims)
    };

    let mut by_model: HashMap<String, Vec<GroupJob>> = HashMap::new();
    let mut singles: Vec<(String, Parsed, u64)> = Vec::new();
    for (bytes, reply, trace) in jobs {
        Metrics::inc(&metrics.bytes_in, bytes.len() as u64);
        if bytes.len() >= 4 && &bytes[0..4] == MAGIC_PARALLEL {
            match ParallelContainer::from_bytes(&bytes) {
                Ok(pc) => singles.push((pc.model.clone(), Parsed::Bbc2(pc, reply), trace)),
                Err(e) => fail(reply, format!("bad container: {e:#}")),
            }
            continue;
        }
        if bytes.len() >= 4 && &bytes[0..4] == MAGIC_HIER {
            match HierContainer::from_bytes(&bytes) {
                Ok(hc) => singles.push((hier_key(&hc), Parsed::Bbc3(hc, reply), trace)),
                Err(e) => fail(reply, format!("bad container: {e:#}")),
            }
            continue;
        }
        if bytes.len() >= 4 && &bytes[0..4] == MAGIC_BBC4 {
            match Bbc4Container::from_bytes(&bytes) {
                Ok(c) => {
                    let key = match &c.model {
                        Bbc4Model::Vae { model, .. } => model.clone(),
                        Bbc4Model::Hier { .. } => match c.hier_shell() {
                            Ok(shell) => hier_key(&shell),
                            Err(e) => {
                                fail(reply, format!("bad container: {e:#}"));
                                continue;
                            }
                        },
                    };
                    singles.push((key, Parsed::Bbc4(c, reply), trace));
                }
                Err(e) => fail(reply, format!("bad container: {e:#}")),
            }
            continue;
        }
        match Container::from_bytes(&bytes) {
            Ok(c) => by_model
                .entry(c.model.clone())
                .or_default()
                .push((c, reply, trace)),
            Err(e) => fail(reply, format!("bad container: {e:#}")),
        }
    }

    let tr = crate::obs::tracer();
    for (key, parsed, trace) in singles {
        if metrics.is_quarantined(&key) {
            let msg = format!("'{key}' is quarantined after repeated panics");
            match parsed {
                Parsed::Bbc2(_, r) | Parsed::Bbc3(_, r) | Parsed::Bbc4(_, r) => fail(r, msg),
            }
            continue;
        }
        let replies = [parsed.reply().clone()];
        let t_unit = Instant::now();
        let run = catch_unwind(AssertUnwindSafe(|| match parsed {
            Parsed::Bbc2(pc, reply) => decode_parallel_container(backends, metrics, pc, reply),
            Parsed::Bbc3(hc, reply) => {
                let workers = match backends {
                    BackendSet::Local(_) => None,
                    BackendSet::Shared { pool, .. } => Some(pool.lanes()),
                };
                decode_hier_container(workers, metrics, hc, reply, hier_cache);
            }
            Parsed::Bbc4(c, reply) => decode_bbc4_container(backends, metrics, c, reply, hier_cache),
        }));
        tr.record(trace, "exec", t_unit, t_unit.elapsed(), 1);
        settle_unit(metrics, sup, &key, run, &replies);
    }

    for (model, group) in by_model {
        if metrics.is_quarantined(&model) {
            for (_, reply, _) in group {
                let msg = format!("model '{model}' is quarantined after repeated panics");
                fail(reply, msg);
            }
            continue;
        }
        let replies: Vec<DecompressReply> = group.iter().map(|(_, r, _)| r.clone()).collect();
        let run = catch_unwind(AssertUnwindSafe(|| {
            decode_group(backends, metrics, &model, group);
        }));
        settle_unit(metrics, sup, &model, run, &replies);
    }
}

/// Route one model's BBC1 decode group to the right executor over the
/// unified [`batched_decode`] loop.
fn decode_group(
    backends: &BackendSet,
    metrics: &Metrics,
    model: &str,
    group: Vec<(Container, DecompressReply, u64)>,
) {
    let reject = |group: Vec<(Container, DecompressReply, u64)>| {
        for (_, reply, _) in group {
            Metrics::inc(&metrics.errors, 1);
            let _ = reply.send(Err(format!("unknown model '{model}'")));
        }
    };
    match backends {
        BackendSet::Local(map) => match map.get(model) {
            Some(b) => {
                let id = b.backend_id();
                let exec = SerialExecutor {
                    backend: b.as_ref(),
                };
                batched_decode(&exec, b.meta(), &id, metrics, group);
            }
            None => reject(group),
        },
        BackendSet::Shared { map, pool } => match map.get(model) {
            Some(b) => {
                let backend: &(dyn Backend + Send + Sync) = &**b;
                let id = backend.backend_id();
                let exec = PooledExecutor { backend, pool };
                batched_decode(&exec, backend.meta(), &id, metrics, group);
            }
            None => reject(group),
        },
    }
}

/// Cross-stream batched decode for one model's BBC1 containers — ONE
/// lock-step loop for both service variants, parameterized by the
/// executor (same byte/behaviour contract as [`batched_encode`]).
fn batched_decode<E: PhaseExecutor>(
    exec: &E,
    meta: &ModelMeta,
    backend_id: &str,
    metrics: &Metrics,
    group: Vec<(Container, DecompressReply, u64)>,
) {
    struct Stream {
        ans: Ans,
        remaining: usize,
        out: Vec<Vec<u8>>,
        /// Request trace id (`0` = untraced).
        trace: u64,
        /// Built once at admission (each container carries its own
        /// config); `None` iff `failed` — constructing per phase would
        /// serialize the pool on the global bucket-table lock.
        core: Option<CodecCore>,
        reply: DecompressReply,
        failed: Option<String>,
        pending_idx: Vec<u32>,
        pending_img: Vec<u8>,
        scratch: CodecScratch,
        /// This round's latent centres / scaled pixels and params.
        ys: Vec<f32>,
        xs: Vec<f32>,
        pending: Option<PixelParams>,
        /// Row of this stream in the current round's batched outputs.
        row: usize,
    }
    let unit_start = Instant::now();
    let mut nn_acc = Duration::ZERO;
    let mut ans_acc = Duration::ZERO;
    let mut streams: Vec<Stream> = group
        .into_iter()
        .map(|(c, reply, trace)| {
            let mut failed = if c.backend_id != backend_id {
                Some(format!(
                    "container encoded with backend '{}', this service runs '{}'",
                    c.backend_id, backend_id
                ))
            } else {
                None
            };
            let core = match CodecCore::new(meta.clone(), c.cfg) {
                Ok(core) => Some(core),
                Err(e) => {
                    if failed.is_none() {
                        failed = Some(format!("{e:#}"));
                    }
                    None
                }
            };
            Stream {
                ans: Ans::from_message(&c.message, c.cfg.clean_seed),
                remaining: c.num_images as usize,
                out: Vec::with_capacity(c.num_images as usize),
                trace,
                core,
                reply,
                failed,
                pending_idx: Vec::new(),
                pending_img: Vec::new(),
                scratch: CodecScratch::new(),
                ys: Vec::new(),
                xs: Vec::new(),
                pending: None,
                row: 0,
            }
        })
        .collect();

    let mut ys_data: Vec<f32> = Vec::new();
    let mut xs_data: Vec<f32> = Vec::new();
    loop {
        let mut active: Vec<&mut Stream> = streams
            .iter_mut()
            .filter(|s| s.failed.is_none() && s.remaining > 0)
            .collect();
        if active.is_empty() {
            break;
        }
        // (3⁻¹) pop priors — across the executor's lanes.
        let t = Instant::now();
        exec.each_stream(&mut active, |s| {
            let s = &mut **s;
            let core = s.core.as_ref().expect("validated at admission");
            core.pop_prior_into(&mut s.ans, &mut s.pending_idx);
            s.ys.clear();
            core.latent_centres_into(&s.pending_idx, &mut s.ys);
        });
        ans_acc += t.elapsed();
        metrics.phase_ans.observe(t.elapsed());
        ys_data.clear();
        for s in active.iter() {
            ys_data.extend_from_slice(&s.ys);
        }
        // (2⁻¹) one batched generative dispatch, pop pixels.
        let ym = Matrix::new(active.len(), meta.latent_dim, std::mem::take(&mut ys_data));
        Metrics::inc(&metrics.nn_calls, 1);
        Metrics::inc(&metrics.nn_items, active.len() as u64);
        let t = Instant::now();
        let r = exec.nn_likelihood(&ym);
        nn_acc += t.elapsed();
        metrics.phase_nn.observe(t.elapsed());
        let params_list = match r {
            Ok(p) => p,
            Err(e) => {
                ys_data = ym.data;
                for s in active.iter_mut() {
                    s.failed = Some(format!("likelihood failed: {e:#}"));
                }
                continue;
            }
        };
        ys_data = ym.data;
        for (s, pp) in active.iter_mut().zip(params_list) {
            s.pending = Some(pp);
        }
        let t = Instant::now();
        exec.each_stream(&mut active, |s| {
            let s = &mut **s;
            let pp = s.pending.take().expect("params distributed above");
            let core = s.core.as_ref().expect("validated at admission");
            s.pending_img = core.pop_pixels_coder_scratch(&mut s.ans, &pp, &mut s.scratch);
            s.xs.clear();
            core.scale_image_into(&s.pending_img, &mut s.xs);
        });
        ans_acc += t.elapsed();
        metrics.phase_ans.observe(t.elapsed());
        xs_data.clear();
        for s in active.iter() {
            xs_data.extend_from_slice(&s.xs);
        }
        // (1⁻¹) one batched recognition dispatch, push bits back.
        let xm = Matrix::new(active.len(), meta.pixels, std::mem::take(&mut xs_data));
        Metrics::inc(&metrics.nn_calls, 1);
        Metrics::inc(&metrics.nn_items, active.len() as u64);
        let t = Instant::now();
        let r = exec.nn_posterior(&xm);
        nn_acc += t.elapsed();
        metrics.phase_nn.observe(t.elapsed());
        match r {
            Ok(posts) => {
                for (r, s) in active.iter_mut().enumerate() {
                    s.row = r;
                }
                let posts = &posts;
                let t = Instant::now();
                exec.each_stream(&mut active, |s| {
                    let s = &mut **s;
                    let core = s.core.as_ref().expect("validated at admission");
                    let (mu, sigma) = posts.row(s.row);
                    core.push_posterior_scratch(
                        &mut s.ans,
                        mu,
                        sigma,
                        &s.pending_idx,
                        &mut s.scratch.gauss,
                    );
                    s.out.push(std::mem::take(&mut s.pending_img));
                    s.remaining -= 1;
                });
                ans_acc += t.elapsed();
                metrics.phase_ans.observe(t.elapsed());
                Metrics::inc(&metrics.images_decoded, active.len() as u64);
            }
            Err(e) => {
                for s in active.iter_mut() {
                    s.failed = Some(format!("posterior failed: {e:#}"));
                }
            }
        }
        xs_data = xm.data;
    }

    let tr = crate::obs::tracer();
    for s in streams {
        if s.trace != 0 {
            let n = s.out.len() as u64;
            tr.record(s.trace, "nn", unit_start, nn_acc, n);
            tr.record(s.trace, "ans", unit_start, ans_acc, n);
        }
        if let Some(msg) = s.failed {
            Metrics::inc(&metrics.errors, 1);
            let _ = s.reply.send(Err(msg));
        } else {
            let mut out = s.out;
            out.reverse(); // stack order → original order
            let _ = s.reply.send(Ok(out));
        }
    }
}

/// Shared BBC2 admission: check the recorded backend id against the
/// hosted backend and build the container's codec — both service
/// variants must accept/reject exactly the same containers.
fn bbc2_codec<'a, B: Backend + ?Sized>(
    pc: &ParallelContainer,
    backend: &'a B,
) -> Result<VaeCodec<'a, B>, String> {
    if pc.backend_id != backend.backend_id() {
        return Err(format!(
            "container encoded with backend '{}', this service runs '{}'",
            pc.backend_id,
            backend.backend_id()
        ));
    }
    VaeCodec::new(backend, pc.cfg).map_err(|e| format!("{e:#}"))
}

/// Decode one chunk-parallel (BBC2) container against the owning model's
/// backend. Thread-bound (`Local`) backends decode chunks sequentially
/// inside the worker thread; `Sync` backends decode the independent
/// chains across the phase pool (speculative first-image scheduling
/// included). Admission is the shared [`bbc2_codec`] — identical
/// accept/reject behaviour across variants.
fn decode_parallel_container(
    backends: &BackendSet,
    metrics: &Metrics,
    pc: ParallelContainer,
    reply: DecompressReply,
) {
    let fail = |msg: String| {
        Metrics::inc(&metrics.errors, 1);
        let _ = reply.send(Err(msg));
    };
    let decode_err = |e: anyhow::Error| format!("parallel container decode failed: {e:#}");
    let decoded: Result<Vec<Vec<u8>>, String> = match backends {
        BackendSet::Local(map) => match map.get(&pc.model) {
            None => Err(format!("unknown model '{}'", pc.model)),
            Some(b) => bbc2_codec(&pc, b.as_ref())
                .and_then(|codec| pc.decode_sequential(&codec).map_err(decode_err)),
        },
        BackendSet::Shared { map, pool } => match map.get(&pc.model) {
            None => Err(format!("unknown model '{}'", pc.model)),
            Some(b) => {
                let backend: &(dyn Backend + Send + Sync) = &**b;
                bbc2_codec(&pc, backend).and_then(|codec| {
                    pc.decode_with_workers(&codec, pool.lanes()).map_err(decode_err)
                })
            }
        },
    };
    match decoded {
        Ok(images) => {
            Metrics::inc(&metrics.images_decoded, images.len() as u64);
            let _ = reply.send(Ok(images));
        }
        Err(msg) => fail(msg),
    }
}

/// Decode one hierarchical (`BBC3`) container. The header is
/// self-describing, so the backend is rebuilt from it instead of looked up
/// in the model map. With `workers: None` (the single-threaded worker)
/// the container's chunks decode **in lock step**: every chain advances
/// one image per round with each round's net evaluations batched across
/// all chains. With `Some(workers)` (the `Sync`-backend fan-out service)
/// the independent chunks decode across the pool instead, speculative
/// first-image scheduling included — the rebuilt `HierVae` is `Sync`.
/// ONE function on purpose: the memoization key and its eviction bound
/// must stay identical across both service variants.
fn decode_hier_container(
    workers: Option<usize>,
    metrics: &Metrics,
    hc: HierContainer,
    reply: DecompressReply,
    cache: &mut HashMap<String, HierVae>,
) {
    let fail = |msg: String| {
        Metrics::inc(&metrics.errors, 1);
        let _ = reply.send(Err(msg));
    };
    let backend = match cached_hier_backend(cache, &hc) {
        Ok(b) => b,
        Err(e) => return fail(format!("{e:#}")),
    };
    let codec = match HierCodec::new(backend, hc.cfg, hc.schedule) {
        Ok(c) => c,
        Err(e) => return fail(format!("{e:#}")),
    };
    let decoded = match workers {
        None => hc.decode_lockstep(&codec),
        Some(w) => hc.decode_with_workers(&codec, w),
    };
    match decoded {
        Ok(images) => {
            Metrics::inc(&metrics.images_decoded, images.len() as u64);
            let _ = reply.send(Ok(images));
        }
        Err(e) => fail(format!("hierarchical container decode failed: {e:#}")),
    }
}

/// Admission for a BBC4 container carrying single-layer pages: same
/// backend-id check as [`bbc2_codec`], against the id the BBC4 header
/// recorded.
fn bbc4_vae_codec<'a, B: Backend + ?Sized>(
    c: &Bbc4Container,
    recorded: &str,
    backend: &'a B,
) -> Result<VaeCodec<'a, B>, String> {
    if recorded != backend.backend_id() {
        return Err(format!(
            "container encoded with backend '{recorded}', this service runs '{}'",
            backend.backend_id()
        ));
    }
    VaeCodec::new(backend, c.cfg).map_err(|e| format!("{e:#}"))
}

/// Decode one paged (`BBC4`) container. The serving path is **strict**:
/// a damaged container is rejected whole (`Bbc4Container::from_bytes`
/// verifies every page CRC and the trailer index) — salvage decoding is
/// an operator decision, exposed through the CLI's `--salvage`, not
/// something a server should silently do to a request. Single-layer
/// pages resolve their model from the hosted map (BBC2 admission rules);
/// hierarchical pages rebuild their backend from the self-describing
/// header through the shared memoization cache.
fn decode_bbc4_container(
    backends: &BackendSet,
    metrics: &Metrics,
    c: Bbc4Container,
    reply: DecompressReply,
    cache: &mut HashMap<String, HierVae>,
) {
    let fail = |msg: String| {
        Metrics::inc(&metrics.errors, 1);
        let _ = reply.send(Err(msg));
    };
    let decode_err = |e: anyhow::Error| format!("BBC4 container decode failed: {e:#}");
    let decoded: Result<Vec<Vec<u8>>, String> = match &c.model {
        Bbc4Model::Vae { model, backend_id } => match backends {
            BackendSet::Local(map) => match map.get(model) {
                None => Err(format!("unknown model '{model}'")),
                Some(b) => bbc4_vae_codec(&c, backend_id, b.as_ref())
                    .and_then(|codec| c.decode_vae(&codec).map_err(decode_err)),
            },
            BackendSet::Shared { map, .. } => match map.get(model) {
                None => Err(format!("unknown model '{model}'")),
                Some(b) => {
                    let backend: &(dyn Backend + Send + Sync) = &**b;
                    bbc4_vae_codec(&c, backend_id, backend)
                        .and_then(|codec| c.decode_vae(&codec).map_err(decode_err))
                }
            },
        },
        Bbc4Model::Hier { .. } => (|| {
            let shell = c.hier_shell().map_err(|e| format!("{e:#}"))?;
            let backend = cached_hier_backend(cache, &shell).map_err(|e| format!("{e:#}"))?;
            let codec =
                HierCodec::new(backend, c.cfg, shell.schedule).map_err(|e| format!("{e:#}"))?;
            c.decode_hier(&codec).map_err(decode_err)
        })(),
    };
    match decoded {
        Ok(images) => {
            Metrics::inc(&metrics.images_decoded, images.len() as u64);
            let _ = reply.send(Ok(images));
        }
        Err(msg) => fail(msg),
    }
}

/// Memoization key for rebuilt hierarchical backends. Covers the FULL
/// header identity — backend_id alone encodes only the seed, and a warm
/// cache must accept/reject exactly the same headers a cold one would
/// ([`HierContainer::build_backend`] checks that weight_seed and
/// backend_id agree). ONE function on purpose: the `CompressHier` encode
/// path and the BBC3 decode path must share cache entries.
fn hier_cache_key(hc: &HierContainer) -> String {
    format!(
        "{}|{}|{}|{}|{}|{:?}",
        hc.backend_id,
        hc.weight_seed,
        hc.pixels,
        hc.hidden,
        hc.likelihood.tag(),
        hc.dims
    )
}

/// Look up (or build and memoize) the backend a header describes.
fn cached_hier_backend<'c>(
    cache: &'c mut HashMap<String, HierVae>,
    hc: &HierContainer,
) -> Result<&'c HierVae> {
    let key = hier_cache_key(hc);
    if !cache.contains_key(&key) {
        let backend = hc.build_backend()?;
        if cache.len() >= 8 {
            cache.clear(); // crude bound; rebuilds are correct, just slow
        }
        cache.insert(key.clone(), backend);
    }
    Ok(cache.get(&key).expect("inserted above"))
}

/// Run one hierarchical compress job (one containment unit). Chunks
/// within a job encode across the phase pool when the service owns one;
/// bytes do not depend on the worker count.
fn compress_hier_job(
    backends: &BackendSet,
    params: &ServiceParams,
    metrics: &Metrics,
    job: HierJob,
    cache: &mut HashMap<String, HierVae>,
) {
    let workers = match backends {
        BackendSet::Local(_) => 1,
        BackendSet::Shared { pool, .. } => pool.lanes(),
    };
    let (spec, images, reply, _trace) = job;
    match encode_hier(&spec, &images, params, workers, cache) {
        Ok(bytes) => {
            Metrics::inc(&metrics.images_encoded, images.len() as u64);
            Metrics::inc(&metrics.bytes_out, bytes.len() as u64);
            let _ = reply.send(Ok(bytes));
        }
        Err(e) => {
            Metrics::inc(&metrics.errors, 1);
            let _ = reply.send(Err(format!("{e:#}")));
        }
    }
}

/// Encode one hierarchical (`CompressHier`) job. The spec is expanded
/// into a header-equivalent [`HierContainer`] so admission — seed,
/// parameter budget, backend-id agreement — is exactly the decode path's
/// [`HierContainer::build_backend`], and the rebuilt backend lands in the
/// same memo cache BBC3 decodes read.
fn encode_hier(
    spec: &HierSpec,
    images: &[Vec<u8>],
    params: &ServiceParams,
    workers: usize,
    cache: &mut HashMap<String, HierVae>,
) -> Result<Vec<u8>> {
    if spec.dims.is_empty() {
        bail!("hierarchical compress needs at least one latent layer");
    }
    if images.is_empty() {
        bail!("hierarchical compress with no images");
    }
    let pixels = images[0].len();
    if pixels == 0 {
        bail!("hierarchical compress with zero-pixel images");
    }
    if images.iter().any(|i| i.len() != pixels) {
        bail!("hierarchical compress images must share one size");
    }
    if matches!(spec.likelihood, Likelihood::Bernoulli)
        && images.iter().flatten().any(|&p| p > 1)
    {
        bail!("Bernoulli hierarchy codes binary pixels; got a value > 1");
    }
    let hc = HierContainer {
        model: format!("hier{}", spec.dims.len()),
        backend_id: format!("hier-native-s{}", spec.seed),
        schedule: spec.schedule,
        cfg: params.bbans,
        likelihood: spec.likelihood,
        hidden: spec.hidden,
        weight_seed: spec.seed,
        pixels: pixels as u32,
        dims: spec.dims.clone(),
        chunks: Vec::new(),
    };
    let backend = cached_hier_backend(cache, &hc)?;
    let codec = HierCodec::new(backend, params.bbans, spec.schedule)?;
    let container =
        HierContainer::encode_with_workers(&codec, images, spec.chunks.max(1) as usize, workers)?;
    Ok(container.to_bytes())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::vae::NativeVae;

    fn toy_meta() -> ModelMeta {
        ModelMeta {
            name: "toy".into(),
            pixels: 36,
            latent_dim: 6,
            hidden: 10,
            likelihood: Likelihood::Bernoulli,
            test_elbo_bpd: f64::NAN,
        }
    }

    fn test_service(max_jobs: usize, delay_ms: u64) -> ModelService {
        let params = ServiceParams {
            max_jobs,
            max_batch_delay: Duration::from_millis(delay_ms),
            ..Default::default()
        };
        ModelService::spawn_with(params, || {
            let mut map: HashMap<String, Box<dyn Backend>> = HashMap::new();
            map.insert("toy".into(), Box::new(NativeVae::random(toy_meta(), 77)));
            Ok(map)
        })
    }

    fn sample_images(n: usize, seed: u64) -> Vec<Vec<u8>> {
        let mut rng = crate::util::rng::Rng::new(seed);
        (0..n)
            .map(|_| (0..36).map(|_| (rng.f64() < 0.3) as u8).collect())
            .collect()
    }

    /// The `Sync`-backend pooled variant of [`test_service`]: same model
    /// (same meta, same seed → same weights), phases spread over `fanout`
    /// workers.
    fn test_service_sync(max_jobs: usize, delay_ms: u64, fanout: usize) -> ModelService {
        let params = ServiceParams {
            max_jobs,
            max_batch_delay: Duration::from_millis(delay_ms),
            fanout_workers: fanout,
            ..Default::default()
        };
        ModelService::spawn_with_sync(params, || {
            let mut map: HashMap<String, SharedBackend> = HashMap::new();
            map.insert("toy".into(), Arc::new(NativeVae::random(toy_meta(), 77)));
            Ok(map)
        })
    }

    /// The fan-out service must produce byte-identical containers to the
    /// single-threaded worker at every fan-out width, and each service
    /// must decode the other's output — the coordinator-level face of the
    /// ISSUE 5 determinism contract.
    #[test]
    fn sync_service_bytes_match_serial_service() {
        let serial = test_service(4, 1);
        let images = sample_images(9, 31);
        let reference = serial.handle().compress("toy", images.clone()).unwrap();
        for fanout in [1usize, 3] {
            let sync = test_service_sync(4, 1, fanout);
            let h = sync.handle();
            let bytes = h.compress("toy", images.clone()).unwrap();
            assert_eq!(bytes, reference, "fanout={fanout} changed container bytes");
            assert_eq!(h.decompress(reference.clone()).unwrap(), images);
            sync.shutdown();
        }
        assert_eq!(serial.handle().decompress(reference).unwrap(), images);
        serial.shutdown();
    }

    #[test]
    fn sync_service_concurrent_requests_roundtrip_and_batch() {
        let svc = test_service_sync(8, 30, 2);
        let h = svc.handle();
        let mut threads = Vec::new();
        for t in 0..6 {
            let h = h.clone();
            threads.push(std::thread::spawn(move || {
                let images = sample_images(5, 300 + t);
                let c = h.compress("toy", images.clone()).unwrap();
                let out = h.decompress(c).unwrap();
                assert_eq!(out, images);
            }));
        }
        for t in threads {
            t.join().unwrap();
        }
        let mbs = svc.metrics.mean_batch_size();
        assert!(mbs > 1.5, "expected cross-stream batching, got {mbs:.2}");
        svc.shutdown();
    }

    #[test]
    fn sync_service_decodes_chunked_and_hier_containers() {
        use crate::bbans::hierarchy::Schedule;
        use crate::model::hierarchy::{HierMeta, HierVae};
        // Offline BBC2 from the same toy model the service hosts.
        let backend = NativeVae::random(toy_meta(), 77);
        let codec = VaeCodec::new(&backend, BbAnsConfig::default()).unwrap();
        let images = sample_images(9, 21);
        let pc = crate::bbans::container::ParallelContainer::encode_with(&codec, &images, 3)
            .unwrap();
        // Offline BBC3 (self-describing header).
        let hmeta = HierMeta {
            name: "hier2".into(),
            pixels: 36,
            dims: vec![6, 4],
            hidden: 10,
            likelihood: Likelihood::Bernoulli,
        };
        let hbackend = HierVae::random(hmeta, 99);
        let hcodec = HierCodec::new(&hbackend, BbAnsConfig::default(), Schedule::BitSwap).unwrap();
        let hc = HierContainer::encode_with_workers(&hcodec, &images, 3, 2).unwrap();

        let svc = test_service_sync(4, 1, 3);
        let h = svc.handle();
        assert_eq!(h.decompress(pc.to_bytes()).unwrap(), images);
        assert_eq!(h.decompress(hc.to_bytes()).unwrap(), images);
        // Wrong backend ids still rejected through the fan-out paths.
        let mut bad = pc;
        bad.backend_id = "pjrt-b16".into();
        assert!(h.decompress(bad.to_bytes()).is_err());
        let mut badh = hc;
        badh.backend_id = "hier-native-s1".into();
        assert!(h.decompress(badh.to_bytes()).is_err());
        svc.shutdown();
    }

    #[test]
    fn compress_decompress_roundtrip_through_service() {
        let svc = test_service(4, 1);
        let h = svc.handle();
        let images = sample_images(7, 1);
        let container = h.compress("toy", images.clone()).unwrap();
        let out = h.decompress(container).unwrap();
        assert_eq!(out, images);
        svc.shutdown();
    }

    #[test]
    fn concurrent_requests_get_batched() {
        let svc = test_service(8, 30);
        let h = svc.handle();
        let mut threads = Vec::new();
        for t in 0..6 {
            let h = h.clone();
            threads.push(std::thread::spawn(move || {
                let images = sample_images(5, 100 + t);
                let c = h.compress("toy", images.clone()).unwrap();
                let out = h.decompress(c).unwrap();
                assert_eq!(out, images);
            }));
        }
        for t in threads {
            t.join().unwrap();
        }
        // With 6 concurrent 5-image streams and a 30ms window, NN calls
        // must have been shared across streams.
        let mbs = svc.metrics.mean_batch_size();
        assert!(mbs > 1.5, "expected cross-stream batching, got {mbs:.2}");
        svc.shutdown();
    }

    #[test]
    fn unknown_model_and_bad_container_error_cleanly() {
        let svc = test_service(4, 1);
        let h = svc.handle();
        assert!(h.compress("nope", sample_images(1, 3)).is_err());
        assert!(h.decompress(vec![1, 2, 3]).is_err());
        let stats = h.stats_json().unwrap();
        assert!(stats.contains("errors"));
        svc.shutdown();
    }

    #[test]
    fn wrong_backend_container_rejected() {
        let svc = test_service(4, 1);
        let h = svc.handle();
        let images = sample_images(2, 9);
        let c = h.compress("toy", images).unwrap();
        let mut parsed = Container::from_bytes(&c).unwrap();
        parsed.backend_id = "pjrt-b16".into();
        assert!(h.decompress(parsed.to_bytes()).is_err());
        svc.shutdown();
    }

    #[test]
    fn chunk_parallel_container_decodes_through_service() {
        // A BBC2 container produced offline by the chunk-parallel encoder
        // must decode through the serving path. The test backend mirrors
        // test_service's factory (same meta, same seed → same weights).
        let backend = NativeVae::random(toy_meta(), 77);
        let codec = VaeCodec::new(&backend, BbAnsConfig::default()).unwrap();
        let images = sample_images(9, 21);
        let pc = crate::bbans::container::ParallelContainer::encode_with(&codec, &images, 3)
            .unwrap();

        let svc = test_service(4, 1);
        let h = svc.handle();
        assert_eq!(h.decompress(pc.to_bytes()).unwrap(), images);

        // Wrong backend id still rejected for BBC2.
        let mut bad = pc;
        bad.backend_id = "pjrt-b16".into();
        assert!(h.decompress(bad.to_bytes()).is_err());
        svc.shutdown();
    }

    #[test]
    fn hier_container_decodes_through_service() {
        // A BBC3 container produced offline decodes through the serving
        // path via its self-describing header (lock-step across chunks).
        use crate::bbans::hierarchy::Schedule;
        use crate::model::hierarchy::{HierMeta, HierVae};
        let meta = HierMeta {
            name: "hier2".into(),
            pixels: 36,
            dims: vec![6, 4],
            hidden: 10,
            likelihood: Likelihood::Bernoulli,
        };
        let backend = HierVae::random(meta, 99);
        let codec = HierCodec::new(&backend, BbAnsConfig::default(), Schedule::BitSwap).unwrap();
        let images = sample_images(8, 21);
        let hc = HierContainer::encode_with_workers(&codec, &images, 3, 2).unwrap();

        let svc = test_service(4, 1);
        let h = svc.handle();
        assert_eq!(h.decompress(hc.to_bytes()).unwrap(), images);

        // A header whose backend id does not match its weight seed is
        // rejected instead of silently decoding with the wrong model.
        let mut bad = hc;
        bad.backend_id = "hier-native-s1".into();
        assert!(h.decompress(bad.to_bytes()).is_err());
        svc.shutdown();
    }

    #[test]
    fn wrong_image_size_rejected_per_stream() {
        let svc = test_service(4, 1);
        let h = svc.handle();
        let images = vec![vec![0u8; 35]];
        assert!(h.compress("toy", images).is_err());
        // Service still alive for good requests.
        let good = sample_images(2, 4);
        assert!(h.compress("toy", good).is_ok());
        svc.shutdown();
    }

    #[test]
    fn full_queue_rejects_with_overloaded_error() {
        // Hold the worker inside its factory so nothing drains, then
        // overfill the bounded admission queue.
        let (gate_tx, gate_rx) = mpsc::channel::<()>();
        let params = ServiceParams {
            max_jobs: 4,
            max_batch_delay: Duration::from_millis(1),
            queue_cap: 2,
            ..Default::default()
        };
        let svc = ModelService::spawn_with(params, move || {
            gate_rx.recv().ok();
            let mut map: HashMap<String, Box<dyn Backend>> = HashMap::new();
            map.insert("toy".into(), Box::new(NativeVae::random(toy_meta(), 77)));
            Ok(map)
        });
        let h = svc.handle();
        let mut waiters = Vec::new();
        for t in 0..2u64 {
            let h = h.clone();
            waiters.push(std::thread::spawn(move || {
                h.compress("toy", sample_images(1, 400 + t))
            }));
        }
        // Wait until both submissions sit in the queue.
        let t0 = Instant::now();
        while svc.metrics.queue_depth.load(Ordering::Relaxed) < 2 {
            assert!(t0.elapsed() < Duration::from_secs(10), "jobs never queued");
            std::thread::sleep(Duration::from_millis(1));
        }
        let err = h.compress("toy", sample_images(1, 9)).unwrap_err();
        assert!(err.to_string().contains("overloaded"), "got: {err}");
        assert!(svc.metrics.rejected.load(Ordering::Relaxed) >= 1);
        // Release the worker; the queued jobs complete normally.
        gate_tx.send(()).unwrap();
        for w in waiters {
            assert!(w.join().unwrap().is_ok());
        }
        svc.shutdown();
    }

    /// A backend whose recognition net panics on every dispatch —
    /// the containment tests' poison pill.
    struct PanicVae(NativeVae);

    impl Backend for PanicVae {
        fn meta(&self) -> &ModelMeta {
            self.0.meta()
        }
        fn backend_id(&self) -> String {
            self.0.backend_id()
        }
        fn posterior(&self, _xs: &[&[f32]]) -> Result<Vec<(Vec<f32>, Vec<f32>)>> {
            panic!("injected: recognition net exploded")
        }
        fn likelihood(&self, ys: &[&[f32]]) -> Result<Vec<PixelParams>> {
            self.0.likelihood(ys)
        }
    }

    #[test]
    fn worker_survives_panicking_backend_and_quarantines_it() {
        let params = ServiceParams {
            max_jobs: 4,
            max_batch_delay: Duration::from_millis(1),
            quarantine_after: 2,
            ..Default::default()
        };
        let svc = ModelService::spawn_with(params, || {
            let mut map: HashMap<String, Box<dyn Backend>> = HashMap::new();
            map.insert("toy".into(), Box::new(NativeVae::random(toy_meta(), 77)));
            let mut boom_meta = toy_meta();
            boom_meta.name = "boom".into();
            map.insert(
                "boom".into(),
                Box::new(PanicVae(NativeVae::random(boom_meta, 78))),
            );
            Ok(map)
        });
        let h = svc.handle();
        for i in 0..2 {
            let e = h.compress("boom", sample_images(1, 600 + i)).unwrap_err();
            assert!(e.to_string().contains("internal panic"), "got: {e}");
            assert!(h.is_alive(), "worker died on contained panic {i}");
        }
        // Two consecutive panics tripped the supervisor: the next request
        // fast-fails on the quarantine list without dispatching.
        let e = h.compress("boom", sample_images(1, 9)).unwrap_err();
        assert!(e.to_string().contains("quarantined"), "got: {e}");
        // The healthy model is unaffected throughout.
        let images = sample_images(3, 10);
        let c = h.compress("toy", images.clone()).unwrap();
        assert_eq!(h.decompress(c).unwrap(), images);
        assert!(svc.metrics.panics.load(Ordering::Relaxed) >= 2);
        assert!(svc.metrics.is_quarantined("boom"));
        assert!(h.health_json().contains("boom"));
        assert!(h.is_alive());
        svc.shutdown();
    }

    #[test]
    fn expired_jobs_are_shed_before_dispatch() {
        // Wedge the worker in init so jobs outlive their TTL while
        // queued; a short-TTL job must be shed while its TTL-free
        // round-mate completes normally.
        let (gate_tx, gate_rx) = mpsc::channel::<()>();
        let params = ServiceParams {
            max_jobs: 4,
            max_batch_delay: Duration::from_millis(1),
            ..Default::default()
        };
        let svc = ModelService::spawn_with(params, move || {
            gate_rx.recv().ok();
            let mut map: HashMap<String, Box<dyn Backend>> = HashMap::new();
            map.insert("toy".into(), Box::new(NativeVae::random(toy_meta(), 77)));
            Ok(map)
        });
        let h = svc.handle();
        let hc = h.clone();
        let short = std::thread::spawn(move || {
            hc.compress_with("toy", sample_images(1, 7), Some(Duration::from_millis(10)))
        });
        let hc = h.clone();
        let long = std::thread::spawn(move || hc.compress("toy", sample_images(1, 8)));
        let t0 = Instant::now();
        while svc.metrics.queue_depth.load(Ordering::Relaxed) < 2 {
            assert!(t0.elapsed() < Duration::from_secs(10), "jobs never queued");
            std::thread::sleep(Duration::from_millis(1));
        }
        std::thread::sleep(Duration::from_millis(30)); // let the TTL lapse
        gate_tx.send(()).unwrap();
        let e = short.join().unwrap().unwrap_err();
        assert!(e.to_string().contains("deadline exceeded"), "got: {e}");
        assert!(long.join().unwrap().is_ok());
        assert_eq!(svc.metrics.expired.load(Ordering::Relaxed), 1);
        svc.shutdown();
    }

    #[test]
    fn stats_and_health_served_while_queue_full() {
        // Stats must answer from the handle side even when admission
        // would reject — observability has to survive overload.
        let (gate_tx, gate_rx) = mpsc::channel::<()>();
        let params = ServiceParams {
            max_jobs: 4,
            max_batch_delay: Duration::from_millis(1),
            queue_cap: 1,
            ..Default::default()
        };
        let svc = ModelService::spawn_with(params, move || {
            gate_rx.recv().ok();
            let mut map: HashMap<String, Box<dyn Backend>> = HashMap::new();
            map.insert("toy".into(), Box::new(NativeVae::random(toy_meta(), 77)));
            Ok(map)
        });
        let h = svc.handle();
        let hc = h.clone();
        let waiter = std::thread::spawn(move || hc.compress("toy", sample_images(1, 1)));
        let t0 = Instant::now();
        while svc.metrics.queue_depth.load(Ordering::Relaxed) < 1 {
            assert!(t0.elapsed() < Duration::from_secs(10), "job never queued");
            std::thread::sleep(Duration::from_millis(1));
        }
        // Queue (cap 1) is full: compress rejects, stats + health answer.
        assert!(h.compress("toy", sample_images(1, 2)).is_err());
        let stats = h.stats_json().unwrap();
        assert!(stats.contains("queue_depth"), "got: {stats}");
        assert!(h.health_json().contains("alive"));
        assert!(h.is_alive());
        // The legacy worker-side path shares the queue's fate.
        assert!(h.stats_json_via_worker().is_err());
        gate_tx.send(()).unwrap();
        assert!(waiter.join().unwrap().is_ok());
        svc.shutdown();
    }

    #[test]
    fn shutdown_bounded_under_saturated_queue() {
        // Worker wedged in init, queue saturated: the old blocking
        // shutdown would hang forever on `tx.send`; the bounded one must
        // give up within its patience and detach.
        let (gate_tx, gate_rx) = mpsc::channel::<()>();
        let params = ServiceParams {
            max_jobs: 4,
            max_batch_delay: Duration::from_millis(1),
            queue_cap: 1,
            ..Default::default()
        };
        let svc = ModelService::spawn_with(params, move || {
            gate_rx.recv().ok();
            let mut map: HashMap<String, Box<dyn Backend>> = HashMap::new();
            map.insert("toy".into(), Box::new(NativeVae::random(toy_meta(), 77)));
            Ok(map)
        });
        let h = svc.handle();
        let waiter = std::thread::spawn(move || h.compress("toy", sample_images(1, 1)));
        let metrics = svc.metrics.clone();
        let t0 = Instant::now();
        while metrics.queue_depth.load(Ordering::Relaxed) < 1 {
            assert!(t0.elapsed() < Duration::from_secs(10), "job never queued");
            std::thread::sleep(Duration::from_millis(1));
        }
        let t0 = Instant::now();
        let joined = svc.shutdown_within(Duration::from_millis(200));
        assert!(!joined, "worker cannot have joined while wedged in init");
        assert!(t0.elapsed() < Duration::from_secs(5), "shutdown did not bound");
        // Unwedge the detached worker: the queued caller still gets a
        // terminal reply (service processes the job, then exits).
        gate_tx.send(()).unwrap();
        assert!(waiter.join().unwrap().is_ok());
        let t0 = Instant::now();
        while !metrics.worker_dead.load(Ordering::Relaxed) {
            assert!(t0.elapsed() < Duration::from_secs(10), "worker never exited");
            std::thread::sleep(Duration::from_millis(1));
        }
    }

    #[test]
    fn init_failure_replies_to_every_job_variant() {
        use crate::bbans::hierarchy::Schedule;
        let params = ServiceParams {
            max_batch_delay: Duration::from_millis(1),
            ..Default::default()
        };
        let svc = ModelService::spawn_with(
            params,
            || -> Result<HashMap<String, Box<dyn Backend>>> { bail!("no artifacts here") },
        );
        let h = svc.handle();
        let e = h.compress("toy", sample_images(1, 1)).unwrap_err();
        assert!(e.to_string().contains("backend init failed"), "got: {e}");
        let spec = HierSpec {
            schedule: Schedule::BitSwap,
            likelihood: Likelihood::Bernoulli,
            dims: vec![6, 4],
            hidden: 10,
            seed: 99,
            chunks: 2,
        };
        let e = h.compress_hier(spec, sample_images(1, 2)).unwrap_err();
        assert!(e.to_string().contains("backend init failed"), "got: {e}");
        let e = h.decompress(vec![0u8; 8]).unwrap_err();
        assert!(e.to_string().contains("backend init failed"), "got: {e}");
        // Both stats paths still answer in the init-failure drain loop.
        assert!(h.stats_json_via_worker().is_ok());
        assert!(h.stats_json().is_ok());
        assert!(h.is_alive(), "drain loop keeps the worker alive");
        svc.shutdown();
    }

    #[test]
    fn queued_jobs_after_shutdown_get_terminal_replies() {
        // Jobs stuck in the queue BEHIND a shutdown marker are dropped
        // when the worker exits — their callers must unblock with
        // "service dropped request", never hang on `rx.recv()`.
        let (gate_tx, gate_rx) = mpsc::channel::<()>();
        let params = ServiceParams {
            max_jobs: 1,
            max_batch_delay: Duration::from_millis(1),
            queue_cap: 8,
            ..Default::default()
        };
        let svc = ModelService::spawn_with(params, move || {
            gate_rx.recv().ok();
            let mut map: HashMap<String, Box<dyn Backend>> = HashMap::new();
            map.insert("toy".into(), Box::new(NativeVae::random(toy_meta(), 77)));
            Ok(map)
        });
        let h = svc.handle();
        // Shutdown lands in the queue FIRST (the worker is wedged in
        // init), then jobs pile up behind it.
        svc.tx
            .as_ref()
            .unwrap()
            .try_send(Queued {
                job: Job::Shutdown,
                at: Instant::now(),
                deadline: None,
            })
            .unwrap();
        let mut waiters = Vec::new();
        for t in 0..3u64 {
            let h = h.clone();
            waiters
                .push(std::thread::spawn(move || h.compress("toy", sample_images(1, 500 + t))));
        }
        let t0 = Instant::now();
        while svc.metrics.queue_depth.load(Ordering::Relaxed) < 3 {
            assert!(t0.elapsed() < Duration::from_secs(10), "jobs never queued");
            std::thread::sleep(Duration::from_millis(1));
        }
        gate_tx.send(()).unwrap();
        for w in waiters {
            let e = w.join().unwrap().unwrap_err();
            assert!(e.to_string().contains("service dropped request"), "got: {e}");
        }
        // The worker is already gone; shutdown must return promptly.
        let t0 = Instant::now();
        svc.shutdown();
        assert!(t0.elapsed() < Duration::from_secs(5));
    }

    #[test]
    fn malformed_model_config_errors_instead_of_panicking() {
        let dir = Path::new("/nonexistent");
        // No 'models' at all.
        let c = Json::parse(r#"{"pixels": 36}"#).unwrap();
        let e = format!("{:#}", native_backend(dir, &c, "toy").unwrap_err());
        assert!(e.contains("models"), "got: {e}");
        // Unknown model name.
        let c = Json::parse(r#"{"pixels": 36, "models": {}}"#).unwrap();
        let e = format!("{:#}", native_backend(dir, &c, "nope").unwrap_err());
        assert!(e.contains("nope"), "got: {e}");
        // Model present but missing latent_dim.
        let c = Json::parse(r#"{"pixels": 36, "models": {"toy": {"hidden": 10}}}"#).unwrap();
        let e = format!("{:#}", native_backend(dir, &c, "toy").unwrap_err());
        assert!(e.contains("latent_dim"), "got: {e}");
        // latent_dim has the wrong type.
        let c = Json::parse(
            r#"{"pixels": 36, "models": {"toy": {"latent_dim": "six", "hidden": 10,
                "likelihood": "bernoulli", "weights": "w.bin"}}}"#,
        )
        .unwrap();
        let e = format!("{:#}", native_backend(dir, &c, "toy").unwrap_err());
        assert!(e.contains("latent_dim"), "got: {e}");
        // likelihood has the wrong type.
        let c = Json::parse(
            r#"{"pixels": 36, "models": {"toy": {"latent_dim": 6, "hidden": 10,
                "likelihood": 3, "weights": "w.bin"}}}"#,
        )
        .unwrap();
        let e = format!("{:#}", native_backend(dir, &c, "toy").unwrap_err());
        assert!(e.contains("likelihood"), "got: {e}");
        // A malformed config also routes through the worker's init-failure
        // reply path instead of panicking the worker thread.
        let params = ServiceParams {
            max_batch_delay: Duration::from_millis(1),
            ..Default::default()
        };
        let svc = ModelService::spawn_with_sync(params, || {
            let config = Json::parse(r#"{"pixels": 36, "models": {"toy": {}}}"#).unwrap();
            let mut map: HashMap<String, SharedBackend> = HashMap::new();
            map.insert(
                "toy".into(),
                Arc::new(native_backend(Path::new("/nonexistent"), &config, "toy")?),
            );
            Ok(map)
        });
        let h = svc.handle();
        let e = h.compress("toy", sample_images(1, 1)).unwrap_err();
        assert!(e.to_string().contains("backend init failed"), "got: {e}");
        assert!(h.is_alive());
        svc.shutdown();
    }

    #[test]
    fn hier_compress_is_byte_identical_to_offline_encoder() {
        use crate::bbans::hierarchy::Schedule;
        use crate::model::hierarchy::{HierMeta, HierVae};
        let images = sample_images(8, 41);
        // Offline reference bytes (worker count never changes bytes).
        let hmeta = HierMeta {
            name: "hier2".into(),
            pixels: 36,
            dims: vec![6, 4],
            hidden: 10,
            likelihood: Likelihood::Bernoulli,
        };
        let backend = HierVae::random(hmeta, 99);
        let codec = HierCodec::new(&backend, BbAnsConfig::default(), Schedule::BitSwap).unwrap();
        let reference = HierContainer::encode_with_workers(&codec, &images, 3, 2)
            .unwrap()
            .to_bytes();

        let spec = HierSpec {
            schedule: Schedule::BitSwap,
            likelihood: Likelihood::Bernoulli,
            dims: vec![6, 4],
            hidden: 10,
            seed: 99,
            chunks: 3,
        };
        let serial = test_service(4, 1);
        let h = serial.handle();
        let bytes = h.compress_hier(spec.clone(), images.clone()).unwrap();
        assert_eq!(bytes, reference, "serial executor changed BBC3 bytes");
        assert_eq!(h.decompress(bytes).unwrap(), images);
        serial.shutdown();
        for fanout in [1usize, 3] {
            let sync = test_service_sync(4, 1, fanout);
            let bytes = sync.handle().compress_hier(spec.clone(), images.clone()).unwrap();
            assert_eq!(bytes, reference, "fanout={fanout} changed BBC3 bytes");
            sync.shutdown();
        }
    }

    /// Health JSON carries the service identity fields the stats
    /// snapshot gained (uptime, crate version, SIMD kernel), and they
    /// survive a JSON round-trip.
    #[test]
    fn health_json_roundtrips_identity_fields() {
        let svc = test_service(4, 1);
        let h = svc.handle();
        let j = Json::parse(&h.health_json()).unwrap();
        assert_eq!(
            j.get("version").unwrap().as_str(),
            Some(env!("CARGO_PKG_VERSION"))
        );
        let kernel = j.get("kernel_id").unwrap().as_str().unwrap().to_string();
        assert!(
            ["avx2", "neon", "scalar"].contains(&kernel.as_str()),
            "unexpected kernel id {kernel}"
        );
        assert!(j.get("uptime_s").unwrap().as_f64().unwrap() >= 0.0);
        assert_eq!(j.get("alive").unwrap().as_bool(), Some(true));
        svc.shutdown();
    }

    /// A traced compress + decompress records the full span lifecycle —
    /// admission, queue wait, coding phases, round — under the request's
    /// trace id, and tracing changes no payload bytes.
    #[test]
    fn traced_request_records_lifecycle_spans() {
        let _guard = crate::obs::trace::test_guard();
        let tr = crate::obs::tracer();
        let was = tr.enabled();
        tr.set_enabled(true);

        let svc = test_service(4, 1);
        let h = svc.handle();
        let images = sample_images(3, 55);
        let untraced = h.compress("toy", images.clone()).unwrap();
        let id = tr.next_trace_id();
        let c = h.compress_opts("toy", images.clone(), None, id).unwrap();
        assert_eq!(c, untraced, "tracing must not change container bytes");
        let id2 = tr.next_trace_id();
        assert_eq!(h.decompress_opts(c, None, id2).unwrap(), images);
        svc.shutdown(); // worker flushed its spans at each round's end

        for (trace, expect) in [
            (id, &["admission", "queue", "nn", "ans", "round"][..]),
            (id2, &["admission", "queue", "nn", "ans", "round"][..]),
        ] {
            let spans = tr.spans();
            for name in expect {
                assert!(
                    spans.iter().any(|s| s.trace == trace && s.name == *name),
                    "missing span {name} for trace {trace}"
                );
            }
        }
        tr.set_enabled(was);
    }

    #[test]
    fn hier_compress_validates_input() {
        use crate::bbans::hierarchy::Schedule;
        let spec = HierSpec {
            schedule: Schedule::BitSwap,
            likelihood: Likelihood::Bernoulli,
            dims: vec![6, 4],
            hidden: 10,
            seed: 99,
            chunks: 2,
        };
        let svc = test_service(4, 1);
        let h = svc.handle();
        assert!(h.compress_hier(spec.clone(), vec![]).is_err());
        let ragged = vec![vec![0u8; 36], vec![0u8; 35]];
        assert!(h.compress_hier(spec.clone(), ragged).is_err());
        let mut nonbinary = vec![0u8; 36];
        nonbinary[0] = 2;
        assert!(h.compress_hier(spec.clone(), vec![nonbinary]).is_err());
        // Seed 0 is reserved for artifact-backed models and rejected.
        let mut zero_seed = spec;
        zero_seed.seed = 0;
        assert!(h.compress_hier(zero_seed, sample_images(1, 5)).is_err());
        // Service still alive for good requests.
        assert!(h.compress("toy", sample_images(2, 6)).is_ok());
        svc.shutdown();
    }
}
