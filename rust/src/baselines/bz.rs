//! bz2-style block-sorting compressor: BWT → MTF → zero-RLE → Huffman.
//!
//! Structurally faithful to bzip2 (the paper's `bz2` baseline) while
//! keeping a simple container: per block we store the primary index, a
//! canonical Huffman table (256 nibble-packed code lengths) and the coded
//! symbols. Cross-validated for *rate sanity* (not format) against the
//! real `bzip2` crate in the baseline benches.

use super::bwt::{bwt_forward, bwt_inverse, mtf_forward, mtf_inverse, zrle_forward, zrle_inverse};
use super::huffman::{code_lengths, Decoder, Encoder};
use crate::util::bitio::{LsbReader, LsbWriter};
use anyhow::{bail, Context, Result};

pub const MAGIC: &[u8; 4] = b"BZR1";
pub const DEFAULT_BLOCK: usize = 256 * 1024;

pub fn compress(data: &[u8], block_size: usize) -> Vec<u8> {
    let mut out = Vec::new();
    out.extend_from_slice(MAGIC);
    out.extend_from_slice(&(data.len() as u64).to_le_bytes());
    for block in data.chunks(block_size.max(1)) {
        let (last, primary) = bwt_forward(block);
        let mtf = mtf_forward(&last);
        let z = zrle_forward(&mtf);

        let mut freq = [0u64; 256];
        for &b in &z {
            freq[b as usize] += 1;
        }
        let lens = code_lengths(&freq, 15);
        let enc = Encoder::from_lengths(&lens);
        let mut w = LsbWriter::new();
        for &b in &z {
            enc.write(&mut w, b as usize);
        }
        let payload = w.finish();

        out.extend_from_slice(&(block.len() as u32).to_le_bytes());
        out.extend_from_slice(&(primary as u32).to_le_bytes());
        out.extend_from_slice(&(z.len() as u32).to_le_bytes());
        out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        // Nibble-packed code lengths (256 * 4 bits = 128 bytes).
        for pair in lens.chunks(2) {
            out.push((pair[0] as u8) | ((pair.get(1).copied().unwrap_or(0) as u8) << 4));
        }
        out.extend_from_slice(&payload);
    }
    out
}

pub fn decompress(data: &[u8]) -> Result<Vec<u8>> {
    if data.len() < 12 || &data[0..4] != MAGIC {
        bail!("bad BZR1 header");
    }
    let total = u64::from_le_bytes(data[4..12].try_into().unwrap()) as usize;
    let mut out = Vec::with_capacity(total);
    let mut pos = 12usize;
    while out.len() < total {
        if pos + 16 > data.len() {
            bail!("truncated block header");
        }
        let block_len = u32::from_le_bytes(data[pos..pos + 4].try_into().unwrap()) as usize;
        let primary = u32::from_le_bytes(data[pos + 4..pos + 8].try_into().unwrap()) as usize;
        let n_syms = u32::from_le_bytes(data[pos + 8..pos + 12].try_into().unwrap()) as usize;
        let payload_len = u32::from_le_bytes(data[pos + 12..pos + 16].try_into().unwrap()) as usize;
        pos += 16;
        if pos + 128 + payload_len > data.len() {
            bail!("truncated block body");
        }
        let mut lens = vec![0u32; 256];
        for i in 0..128 {
            lens[2 * i] = (data[pos + i] & 0x0f) as u32;
            lens[2 * i + 1] = (data[pos + i] >> 4) as u32;
        }
        pos += 128;
        let payload = &data[pos..pos + payload_len];
        pos += payload_len;

        let z = if n_syms == 0 {
            Vec::new()
        } else {
            let dec = Decoder::from_lengths(&lens).context("block Huffman table")?;
            let mut r = LsbReader::new(payload);
            let mut z = Vec::with_capacity(n_syms);
            for _ in 0..n_syms {
                z.push(dec.read(&mut r)? as u8);
            }
            z
        };
        let mtf = zrle_inverse(&z)?;
        if mtf.len() != block_len {
            bail!("block length mismatch: {} vs {block_len}", mtf.len());
        }
        let last = mtf_inverse(&mtf);
        out.extend_from_slice(&bwt_inverse(&last, primary));
    }
    if out.len() != total {
        bail!("total length mismatch");
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check_bytes;

    #[test]
    fn roundtrip_property() {
        check_bytes(51, 40, 5000, |data| {
            decompress(&compress(data, 1024)).map(|d| d == data).unwrap_or(false)
        });
    }

    #[test]
    fn multi_block_roundtrip() {
        let data: Vec<u8> = (0..10_000u32).map(|i| (i % 37) as u8).collect();
        let c = compress(&data, 1000); // 10 blocks
        assert_eq!(decompress(&c).unwrap(), data);
    }

    #[test]
    fn empty_input() {
        let c = compress(&[], 1024);
        assert_eq!(decompress(&c).unwrap(), Vec::<u8>::new());
    }

    #[test]
    fn text_compresses_well() {
        let data: Vec<u8> = b"the quick brown fox jumps over the lazy dog. "
            .iter()
            .cycle()
            .take(20_000)
            .copied()
            .collect();
        let c = compress(&data, DEFAULT_BLOCK);
        assert!(
            c.len() < data.len() / 5,
            "bz-style should crush repetitive text: {} -> {}",
            data.len(),
            c.len()
        );
        assert_eq!(decompress(&c).unwrap(), data);
    }

    #[test]
    fn rejects_corruption() {
        let data = b"some block sorted data".repeat(50);
        let c = compress(&data, 4096);
        assert!(decompress(&c[..c.len() - 3]).is_err());
        let mut bad = c.clone();
        bad[0] = b'X';
        assert!(decompress(&bad).is_err());
    }
}
