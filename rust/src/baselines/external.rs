//! Reference codecs from the `flate2` and `bzip2` crates.
//!
//! These exist purely to *cross-validate* our from-scratch baselines:
//! format interop for gzip (tested in `gzip.rs` and the integration
//! suite) and rate sanity for the bz-style codec (our container differs
//! from bzip2's, so only rates are compared).
//!
//! The reference crates are **not vendored** in this offline workspace,
//! so the whole module is gated behind the `external-codecs` feature;
//! without it the cross-validation tests and benches are compiled out
//! and the from-scratch implementations stand on their own test suites.

#[cfg(feature = "external-codecs")]
mod real {
    use std::io::{Read, Write};

    use anyhow::{Context, Result};

    pub fn flate2_gzip(data: &[u8]) -> Vec<u8> {
        let mut enc = flate2::write::GzEncoder::new(Vec::new(), flate2::Compression::new(6));
        enc.write_all(data).unwrap();
        enc.finish().unwrap()
    }

    pub fn flate2_gunzip(data: &[u8]) -> Result<Vec<u8>> {
        let mut out = Vec::new();
        flate2::read::GzDecoder::new(data)
            .read_to_end(&mut out)
            .context("flate2 gunzip")?;
        Ok(out)
    }

    pub fn bzip2_compress(data: &[u8]) -> Vec<u8> {
        let mut enc = bzip2::write::BzEncoder::new(Vec::new(), bzip2::Compression::default());
        enc.write_all(data).unwrap();
        enc.finish().unwrap()
    }

    pub fn bzip2_decompress(data: &[u8]) -> Result<Vec<u8>> {
        let mut out = Vec::new();
        bzip2::read::BzDecoder::new(data)
            .read_to_end(&mut out)
            .context("bzip2 decompress")?;
        Ok(out)
    }
}

#[cfg(feature = "external-codecs")]
pub use real::*;

#[cfg(all(test, feature = "external-codecs"))]
mod tests {
    use super::*;

    #[test]
    fn reference_roundtrips() {
        let data = b"reference codec sanity".repeat(100);
        assert_eq!(flate2_gunzip(&flate2_gzip(&data)).unwrap(), data);
        assert_eq!(bzip2_decompress(&bzip2_compress(&data)).unwrap(), data);
    }
}
