//! Baseline codecs, all from scratch (DESIGN.md S9–S12): the comparison
//! column of the paper's Table 2/3. A uniform [`ImageCodec`] interface
//! lets the benchmark harness sweep them.
//!
//! * [`deflate`]/[`gzip`] — RFC 1951/1952 (the paper's `gzip`);
//! * [`bz`] — BWT + MTF + RLE + Huffman (the paper's `bz2`, own container);
//! * [`png`] — real PNG (filters, zlib, CRC chunks);
//! * [`webp`] — simplified VP8L ("WebP-style", see DESIGN.md §5);
//! * [`external`] — the vendored `flate2`/`bzip2` crates, used to
//!   cross-validate our implementations' formats and rates.

pub mod bwt;
pub mod bz;
pub mod deflate;
pub mod external;
pub mod gzip;
pub mod huffman;
pub mod lz77;
pub mod png;
pub mod webp;

use crate::data::Dataset;
use anyhow::Result;

/// How a baseline consumes a dataset.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Granularity {
    /// One compressed object for the concatenated dataset (gzip/bz2 style
    /// — how the paper benchmarks generic byte compressors).
    WholeStream,
    /// One compressed object per image (PNG/WebP style).
    PerImage,
}

/// A baseline image-dataset compressor.
pub trait ImageCodec {
    fn name(&self) -> &'static str;
    fn granularity(&self) -> Granularity;

    /// Compress the dataset into one or more blobs.
    fn compress_dataset(&self, ds: &Dataset) -> Result<Vec<Vec<u8>>>;

    /// Decompress back to images (inverse of `compress_dataset`).
    fn decompress_dataset(
        &self,
        blobs: &[Vec<u8>],
        ds_shape: (usize, usize, usize),
    ) -> Result<Vec<Vec<u8>>>;

    /// Compression rate in bits per pixel over the dataset.
    fn bits_per_dim(&self, ds: &Dataset) -> Result<f64> {
        let blobs = self.compress_dataset(ds)?;
        let total_bytes: usize = blobs.iter().map(|b| b.len()).sum();
        Ok(total_bytes as f64 * 8.0 / ds.raw_bytes() as f64)
    }
}

/// Our gzip over the concatenated image stream.
pub struct GzipCodec {
    pub max_chain: usize,
}

impl ImageCodec for GzipCodec {
    fn name(&self) -> &'static str {
        "gzip"
    }

    fn granularity(&self) -> Granularity {
        Granularity::WholeStream
    }

    fn compress_dataset(&self, ds: &Dataset) -> Result<Vec<Vec<u8>>> {
        Ok(vec![gzip::gzip_compress(&ds.flat(), self.max_chain)])
    }

    fn decompress_dataset(
        &self,
        blobs: &[Vec<u8>],
        (n, rows, cols): (usize, usize, usize),
    ) -> Result<Vec<Vec<u8>>> {
        let flat = gzip::gzip_decompress(&blobs[0])?;
        Ok(split_flat(&flat, n, rows * cols))
    }
}

/// Our bz2-style codec over the concatenated stream.
pub struct BzCodec {
    pub block_size: usize,
}

impl ImageCodec for BzCodec {
    fn name(&self) -> &'static str {
        "bz2-style"
    }

    fn granularity(&self) -> Granularity {
        Granularity::WholeStream
    }

    fn compress_dataset(&self, ds: &Dataset) -> Result<Vec<Vec<u8>>> {
        Ok(vec![bz::compress(&ds.flat(), self.block_size)])
    }

    fn decompress_dataset(
        &self,
        blobs: &[Vec<u8>],
        (n, rows, cols): (usize, usize, usize),
    ) -> Result<Vec<Vec<u8>>> {
        let flat = bz::decompress(&blobs[0])?;
        Ok(split_flat(&flat, n, rows * cols))
    }
}

/// Our PNG, one file per image.
pub struct PngCodec {
    pub bit_depth: u8,
}

impl ImageCodec for PngCodec {
    fn name(&self) -> &'static str {
        "png"
    }

    fn granularity(&self) -> Granularity {
        Granularity::PerImage
    }

    fn compress_dataset(&self, ds: &Dataset) -> Result<Vec<Vec<u8>>> {
        ds.images
            .iter()
            .map(|img| png::encode(img, ds.cols, ds.rows, self.bit_depth))
            .collect()
    }

    fn decompress_dataset(
        &self,
        blobs: &[Vec<u8>],
        _shape: (usize, usize, usize),
    ) -> Result<Vec<Vec<u8>>> {
        blobs.iter().map(|b| png::decode(b).map(|(p, _)| p)).collect()
    }
}

/// Our WebP-style codec, one file per image.
pub struct WebpCodec;

impl ImageCodec for WebpCodec {
    fn name(&self) -> &'static str {
        "webp-style"
    }

    fn granularity(&self) -> Granularity {
        Granularity::PerImage
    }

    fn compress_dataset(&self, ds: &Dataset) -> Result<Vec<Vec<u8>>> {
        ds.images
            .iter()
            .map(|img| webp::encode(img, ds.cols, ds.rows))
            .collect()
    }

    fn decompress_dataset(
        &self,
        blobs: &[Vec<u8>],
        _shape: (usize, usize, usize),
    ) -> Result<Vec<Vec<u8>>> {
        blobs
            .iter()
            .map(|b| webp::decode(b).map(|(p, _, _)| p))
            .collect()
    }
}

fn split_flat(flat: &[u8], n: usize, pixels: usize) -> Vec<Vec<u8>> {
    (0..n)
        .map(|i| flat[i * pixels..(i + 1) * pixels].to_vec())
        .collect()
}

/// The standard baseline suite for a dataset kind.
pub fn standard_suite(binarized: bool) -> Vec<Box<dyn ImageCodec>> {
    vec![
        Box::new(BzCodec {
            block_size: bz::DEFAULT_BLOCK,
        }),
        Box::new(GzipCodec { max_chain: 128 }),
        Box::new(PngCodec {
            bit_depth: if binarized { 1 } else { 8 },
        }),
        Box::new(WebpCodec),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    #[test]
    fn suite_roundtrips_on_digits() {
        let ds = synth::digits(12, 20);
        for codec in standard_suite(false) {
            let blobs = codec.compress_dataset(&ds).unwrap();
            let images = codec
                .decompress_dataset(&blobs, (ds.len(), ds.rows, ds.cols))
                .unwrap();
            assert_eq!(images, ds.images, "{} roundtrip", codec.name());
            let bpd = codec.bits_per_dim(&ds).unwrap();
            assert!(bpd > 0.0 && bpd < 16.0, "{}: {bpd}", codec.name());
        }
    }

    #[test]
    fn suite_roundtrips_on_binarized() {
        let ds = synth::binarize(&synth::digits(12, 21), 3);
        for codec in standard_suite(true) {
            let blobs = codec.compress_dataset(&ds).unwrap();
            let images = codec
                .decompress_dataset(&blobs, (ds.len(), ds.rows, ds.cols))
                .unwrap();
            assert_eq!(images, ds.images, "{} roundtrip", codec.name());
        }
    }

    #[test]
    fn stream_codecs_beat_per_image_on_tiny_images() {
        // Whole-stream codecs exploit cross-image redundancy; per-image
        // containers pay per-file overhead (paper Fig. 1 shows PNG's
        // overhead dominating at 28x28).
        let ds = synth::binarize(&synth::digits(30, 22), 4);
        let gz = GzipCodec { max_chain: 128 }.bits_per_dim(&ds).unwrap();
        let png = PngCodec { bit_depth: 1 }.bits_per_dim(&ds).unwrap();
        assert!(gz < png, "gzip {gz} should beat per-image png {png}");
    }
}
