//! LZ77 match finding with hash chains (DEFLATE-compatible parameters:
//! 32 KiB window, match lengths 3–258), with one-step lazy matching like
//! zlib's default strategy. Shared by the DEFLATE and WebP-style codecs.

pub const MIN_MATCH: usize = 3;
pub const MAX_MATCH: usize = 258;
pub const WINDOW: usize = 32 * 1024;

/// One LZ77 token.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Token {
    Literal(u8),
    /// (length 3..=258, distance 1..=32768)
    Match { len: u16, dist: u16 },
}

const HASH_BITS: u32 = 15;
const HASH_SIZE: usize = 1 << HASH_BITS;

#[inline]
fn hash3(data: &[u8], i: usize) -> usize {
    let v = (data[i] as u32) | ((data[i + 1] as u32) << 8) | ((data[i + 2] as u32) << 16);
    (v.wrapping_mul(0x9E37_79B1) >> (32 - HASH_BITS)) as usize
}

/// Tokenize `data` with hash-chain matching.
///
/// `max_chain` trades compression for speed (zlib level ~6 ≈ 128).
pub fn tokenize(data: &[u8], max_chain: usize) -> Vec<Token> {
    let n = data.len();
    let mut tokens = Vec::with_capacity(n / 2 + 8);
    if n < MIN_MATCH {
        tokens.extend(data.iter().map(|&b| Token::Literal(b)));
        return tokens;
    }
    let mut head = vec![usize::MAX; HASH_SIZE];
    let mut prev = vec![usize::MAX; n];

    let insert = |head: &mut [usize], prev: &mut [usize], i: usize, data: &[u8]| {
        if i + MIN_MATCH <= n {
            let h = hash3(data, i);
            prev[i] = head[h];
            head[h] = i;
        }
    };

    let best_match = |head: &[usize], prev: &[usize], i: usize| -> Option<(usize, usize)> {
        if i + MIN_MATCH > n {
            return None;
        }
        let h = hash3(data, i);
        let mut cand = head[h];
        let mut best_len = MIN_MATCH - 1;
        let mut best_dist = 0usize;
        let max_len = MAX_MATCH.min(n - i);
        let mut chain = 0;
        while cand != usize::MAX && chain < max_chain {
            let dist = i - cand;
            if dist > WINDOW {
                break;
            }
            // Quick reject: check the byte that would extend the best.
            if i + best_len < n && data[cand + best_len] == data[i + best_len] {
                let mut l = 0;
                while l < max_len && data[cand + l] == data[i + l] {
                    l += 1;
                }
                if l > best_len {
                    best_len = l;
                    best_dist = dist;
                    if l >= max_len {
                        break;
                    }
                }
            }
            cand = prev[cand];
            chain += 1;
        }
        if best_len >= MIN_MATCH {
            Some((best_len, best_dist))
        } else {
            None
        }
    };

    let mut i = 0usize;
    let mut pending: Option<(usize, usize)> = None; // lazy: match found at i-1
    while i < n {
        let cur = best_match(&head, &prev, i);
        match (pending.take(), cur) {
            (Some((plen, _pdist)), Some((clen, _))) if clen > plen => {
                // Current match is better: emit literal for i-1, keep
                // evaluating from the current position.
                tokens.push(Token::Literal(data[i - 1]));
                pending = cur;
                insert(&mut head, &mut prev, i, data);
                i += 1;
            }
            (Some((plen, pdist)), _) => {
                // Take the pending match (started at i-1).
                tokens.push(Token::Match {
                    len: plen as u16,
                    dist: pdist as u16,
                });
                // Insert hash entries across the matched span (i-1+1 .. i-1+plen).
                let end = i - 1 + plen;
                while i < end {
                    insert(&mut head, &mut prev, i, data);
                    i += 1;
                }
            }
            (None, Some((clen, cdist))) => {
                if clen >= 32 || i + 1 >= n {
                    // Long enough: take greedily.
                    tokens.push(Token::Match {
                        len: clen as u16,
                        dist: cdist as u16,
                    });
                    let end = i + clen;
                    insert(&mut head, &mut prev, i, data);
                    i += 1;
                    while i < end {
                        insert(&mut head, &mut prev, i, data);
                        i += 1;
                    }
                } else {
                    // Defer: maybe i+1 has a better match (lazy).
                    pending = Some((clen, cdist));
                    insert(&mut head, &mut prev, i, data);
                    i += 1;
                }
            }
            (None, None) => {
                tokens.push(Token::Literal(data[i]));
                insert(&mut head, &mut prev, i, data);
                i += 1;
            }
        }
    }
    if let Some((plen, pdist)) = pending {
        tokens.push(Token::Match {
            len: plen as u16,
            dist: pdist as u16,
        });
    }
    tokens
}

/// Expand tokens back to bytes (the decoder's copy loop).
pub fn expand(tokens: &[Token]) -> Vec<u8> {
    let mut out = Vec::new();
    for t in tokens {
        match *t {
            Token::Literal(b) => out.push(b),
            Token::Match { len, dist } => {
                let start = out.len() - dist as usize;
                for k in 0..len as usize {
                    let b = out[start + k];
                    out.push(b);
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check_bytes, gen_bytes};
    use crate::util::rng::Rng;

    #[test]
    fn tokenize_expand_roundtrip_families() {
        check_bytes(11, 60, 4000, |data| expand(&tokenize(data, 64)) == data);
    }

    #[test]
    fn finds_overlapping_matches() {
        // "aaaa..." compresses to literal + overlapping match (dist 1).
        let data = vec![b'a'; 300];
        let tokens = tokenize(&data, 16);
        assert!(tokens.len() <= 4, "run should be a couple of tokens: {tokens:?}");
        assert_eq!(expand(&tokens), data);
        assert!(tokens.iter().any(|t| matches!(t, Token::Match { dist: 1, .. })));
    }

    #[test]
    fn repeated_phrase_found_at_distance() {
        let mut data = b"the quick brown fox. ".to_vec();
        let phrase = data.clone();
        for _ in 0..10 {
            data.extend_from_slice(&phrase);
        }
        let tokens = tokenize(&data, 64);
        assert!(
            tokens.iter().any(|t| matches!(t, Token::Match { len, .. } if *len as usize >= 20)),
            "should find the repeated phrase: {tokens:?}"
        );
        assert_eq!(expand(&tokens), data);
    }

    #[test]
    fn respects_window_limit() {
        let mut rng = Rng::new(5);
        // Two identical blocks separated by > 32k of noise.
        let block: Vec<u8> = (0..100).map(|_| rng.next_u32() as u8).collect();
        let mut data = block.clone();
        data.extend((0..WINDOW + 100).map(|_| rng.next_u32() as u8));
        data.extend_from_slice(&block);
        let tokens = tokenize(&data, 1024);
        assert_eq!(expand(&tokens), data);
        for t in &tokens {
            if let Token::Match { dist, .. } = t {
                assert!((*dist as usize) <= WINDOW);
            }
        }
    }

    #[test]
    fn matches_never_exceed_bounds() {
        let mut rng = Rng::new(6);
        for case in 0..30 {
            let data = gen_bytes(&mut rng, 2000, case);
            let tokens = tokenize(&data, 32);
            let mut pos = 0usize;
            for t in &tokens {
                match *t {
                    Token::Literal(_) => pos += 1,
                    Token::Match { len, dist } => {
                        assert!((MIN_MATCH..=MAX_MATCH).contains(&(len as usize)));
                        assert!(dist as usize >= 1 && dist as usize <= pos);
                        pos += len as usize;
                    }
                }
            }
            assert_eq!(pos, data.len());
        }
    }
}
