//! DEFLATE (RFC 1951) from scratch: LZ77 tokens → dynamic/fixed/stored
//! Huffman blocks. Cross-validated against `flate2` (miniz_oxide) in both
//! directions in `rust/tests/baselines_roundtrip.rs`.

use super::huffman::{code_lengths, Decoder, Encoder};
use super::lz77::{self, Token};
use crate::util::bitio::{LsbReader, LsbWriter};
use anyhow::{bail, Result};

/// Length code table: (code 257..=285) → (extra bits, base length).
const LEN_TABLE: [(u32, u16); 29] = [
    (0, 3), (0, 4), (0, 5), (0, 6), (0, 7), (0, 8), (0, 9), (0, 10),
    (1, 11), (1, 13), (1, 15), (1, 17), (2, 19), (2, 23), (2, 27), (2, 31),
    (3, 35), (3, 43), (3, 51), (3, 59), (4, 67), (4, 83), (4, 99), (4, 115),
    (5, 131), (5, 163), (5, 195), (5, 227), (0, 258),
];

/// Distance code table: code → (extra bits, base distance).
const DIST_TABLE: [(u32, u16); 30] = [
    (0, 1), (0, 2), (0, 3), (0, 4), (1, 5), (1, 7), (2, 9), (2, 13),
    (3, 17), (3, 25), (4, 33), (4, 49), (5, 65), (5, 97), (6, 129), (6, 193),
    (7, 257), (7, 385), (8, 513), (8, 769), (9, 1025), (9, 1537),
    (10, 2049), (10, 3073), (11, 4097), (11, 6145), (12, 8193), (12, 12289),
    (13, 16385), (13, 24577),
];

/// Order in which code-length-code lengths are stored in the header.
const CLC_ORDER: [usize; 19] = [
    16, 17, 18, 0, 8, 7, 9, 6, 10, 5, 11, 4, 12, 3, 13, 2, 14, 1, 15,
];

#[inline]
fn length_code(len: u16) -> usize {
    debug_assert!((3..=258).contains(&len));
    match LEN_TABLE.iter().rposition(|&(_, base)| base <= len) {
        Some(28) if len < 258 => 27, // 258 is its own code; 227..=257 use code 27
        Some(i) => i,
        None => unreachable!(),
    }
}

#[inline]
fn dist_code(dist: u16) -> usize {
    debug_assert!(dist >= 1);
    DIST_TABLE.iter().rposition(|&(_, base)| base <= dist).unwrap()
}

/// Compress with dynamic-Huffman blocks (one block; inputs here are small
/// images/datasets — block splitting is a rate refinement we skip).
pub fn compress(data: &[u8], max_chain: usize) -> Vec<u8> {
    let tokens = lz77::tokenize(data, max_chain);
    let mut w = LsbWriter::new();
    write_dynamic_block(&mut w, &tokens, true);
    w.finish()
}

/// Decompress a DEFLATE stream.
pub fn decompress(data: &[u8]) -> Result<Vec<u8>> {
    let mut r = LsbReader::new(data);
    let mut out = Vec::new();
    loop {
        let bfinal = r.read_bits(1).ok_or_else(|| anyhow::anyhow!("eof at block header"))?;
        let btype = r.read_bits(2).ok_or_else(|| anyhow::anyhow!("eof at block type"))?;
        match btype {
            0 => read_stored_block(&mut r, &mut out)?,
            1 => {
                let (lit, dist) = fixed_decoders()?;
                read_huffman_block(&mut r, &lit, &dist, &mut out)?;
            }
            2 => {
                let (lit, dist) = read_dynamic_header(&mut r)?;
                read_huffman_block(&mut r, &lit, &dist, &mut out)?;
            }
            _ => bail!("reserved block type"),
        }
        if bfinal == 1 {
            break;
        }
    }
    Ok(out)
}

// ------------------------------------------------------------- encoding

fn write_dynamic_block(w: &mut LsbWriter, tokens: &[Token], bfinal: bool) {
    // Symbol statistics.
    let mut lit_freq = [0u64; 286];
    let mut dist_freq = [0u64; 30];
    for t in tokens {
        match *t {
            Token::Literal(b) => lit_freq[b as usize] += 1,
            Token::Match { len, dist } => {
                lit_freq[257 + length_code(len)] += 1;
                dist_freq[dist_code(dist)] += 1;
            }
        }
    }
    lit_freq[256] += 1; // end of block

    let lit_lens = code_lengths(&lit_freq, 15);
    let mut dist_lens = code_lengths(&dist_freq, 15);
    // DEFLATE requires at least one distance code length to be present.
    if dist_lens.iter().all(|&l| l == 0) {
        dist_lens[0] = 1;
    }

    w.write_bits(bfinal as u64, 1);
    w.write_bits(2, 2); // dynamic

    // HLIT/HDIST.
    let hlit = 286usize; // keep all (simplest header; costs a few bytes)
    let hdist = 30usize;
    w.write_bits((hlit - 257) as u64, 5);
    w.write_bits((hdist - 1) as u64, 5);

    // Code-length-code over the concatenated length arrays with RLE.
    let all_lens: Vec<u32> = lit_lens
        .iter()
        .take(hlit)
        .chain(dist_lens.iter().take(hdist))
        .copied()
        .collect();
    let clc_syms = rle_code_lengths(&all_lens);
    let mut clc_freq = [0u64; 19];
    for &(sym, _) in &clc_syms {
        clc_freq[sym] += 1;
    }
    let clc_lens = code_lengths(&clc_freq, 7);

    let hclen_full: Vec<u32> = CLC_ORDER.iter().map(|&i| clc_lens[i]).collect();
    let hclen = hclen_full
        .iter()
        .rposition(|&l| l > 0)
        .map(|p| p + 1)
        .unwrap_or(4)
        .max(4);
    w.write_bits((hclen - 4) as u64, 4);
    for &l in hclen_full.iter().take(hclen) {
        w.write_bits(l as u64, 3);
    }
    let clc_enc = Encoder::from_lengths(&clc_lens);
    for &(sym, extra) in &clc_syms {
        clc_enc.write(w, sym);
        match sym {
            16 => w.write_bits(extra as u64, 2),
            17 => w.write_bits(extra as u64, 3),
            18 => w.write_bits(extra as u64, 7),
            _ => {}
        }
    }

    // Token stream.
    let lit_enc = Encoder::from_lengths(&lit_lens);
    let dist_enc = Encoder::from_lengths(&dist_lens);
    for t in tokens {
        match *t {
            Token::Literal(b) => lit_enc.write(w, b as usize),
            Token::Match { len, dist } => {
                let lc = length_code(len);
                lit_enc.write(w, 257 + lc);
                let (eb, base) = LEN_TABLE[lc];
                if eb > 0 {
                    w.write_bits((len - base) as u64, eb);
                }
                let dc = dist_code(dist);
                dist_enc.write(w, dc);
                let (eb, base) = DIST_TABLE[dc];
                if eb > 0 {
                    w.write_bits((dist - base) as u64, eb);
                }
            }
        }
    }
    lit_enc.write(w, 256); // end of block
}

/// RLE for the code-length sequence (symbols 0..15 literal, 16 repeat
/// previous 3-6, 17 zero-run 3-10, 18 zero-run 11-138).
fn rle_code_lengths(lens: &[u32]) -> Vec<(usize, u32)> {
    let mut out = Vec::new();
    let mut i = 0;
    while i < lens.len() {
        let v = lens[i];
        let mut run = 1;
        while i + run < lens.len() && lens[i + run] == v {
            run += 1;
        }
        if v == 0 {
            let mut left = run;
            while left >= 3 {
                let take = left.min(138);
                if take >= 11 {
                    out.push((18, (take - 11) as u32));
                } else {
                    out.push((17, (take - 3) as u32));
                }
                left -= take;
            }
            for _ in 0..left {
                out.push((0, 0));
            }
        } else {
            out.push((v as usize, 0));
            let mut left = run - 1;
            while left >= 3 {
                let take = left.min(6);
                out.push((16, (take - 3) as u32));
                left -= take;
            }
            for _ in 0..left {
                out.push((v as usize, 0));
            }
        }
        i += run;
    }
    out
}

// ------------------------------------------------------------- decoding

fn read_stored_block(r: &mut LsbReader, out: &mut Vec<u8>) -> Result<()> {
    // Align to byte; LEN + NLEN follow.
    let (data, mut pos) = {
        let (d, p) = r.align_and_rest();
        (d, p)
    };
    if pos + 4 > data.len() {
        bail!("stored block header truncated");
    }
    let len = u16::from_le_bytes([data[pos], data[pos + 1]]) as usize;
    let nlen = u16::from_le_bytes([data[pos + 2], data[pos + 3]]);
    if nlen != !(len as u16) {
        bail!("stored block LEN/NLEN mismatch");
    }
    pos += 4;
    if pos + len > data.len() {
        bail!("stored block body truncated");
    }
    out.extend_from_slice(&data[pos..pos + len]);
    r.seek_to_byte(pos + len);
    Ok(())
}

fn fixed_decoders() -> Result<(Decoder, Decoder)> {
    let mut lit_lens = vec![0u32; 288];
    for (i, l) in lit_lens.iter_mut().enumerate() {
        *l = match i {
            0..=143 => 8,
            144..=255 => 9,
            256..=279 => 7,
            _ => 8,
        };
    }
    let dist_lens = vec![5u32; 30];
    Ok((
        Decoder::from_lengths(&lit_lens)?,
        Decoder::from_lengths(&dist_lens)?,
    ))
}

fn read_dynamic_header(r: &mut LsbReader) -> Result<(Decoder, Decoder)> {
    let hlit = r.read_bits(5).ok_or_else(|| anyhow::anyhow!("eof"))? as usize + 257;
    let hdist = r.read_bits(5).ok_or_else(|| anyhow::anyhow!("eof"))? as usize + 1;
    let hclen = r.read_bits(4).ok_or_else(|| anyhow::anyhow!("eof"))? as usize + 4;
    if hlit > 286 || hdist > 30 {
        bail!("bad HLIT/HDIST");
    }
    let mut clc_lens = vec![0u32; 19];
    for k in 0..hclen {
        clc_lens[CLC_ORDER[k]] =
            r.read_bits(3).ok_or_else(|| anyhow::anyhow!("eof"))? as u32;
    }
    let clc = Decoder::from_lengths(&clc_lens)?;
    let mut lens = Vec::with_capacity(hlit + hdist);
    while lens.len() < hlit + hdist {
        let sym = clc.read(r)?;
        match sym {
            0..=15 => lens.push(sym as u32),
            16 => {
                let prev = *lens.last().ok_or_else(|| anyhow::anyhow!("repeat at start"))?;
                let n = 3 + r.read_bits(2).ok_or_else(|| anyhow::anyhow!("eof"))? as usize;
                for _ in 0..n {
                    lens.push(prev);
                }
            }
            17 => {
                let n = 3 + r.read_bits(3).ok_or_else(|| anyhow::anyhow!("eof"))? as usize;
                lens.resize(lens.len() + n, 0);
            }
            18 => {
                let n = 11 + r.read_bits(7).ok_or_else(|| anyhow::anyhow!("eof"))? as usize;
                lens.resize(lens.len() + n, 0);
            }
            _ => bail!("bad code-length symbol {sym}"),
        }
    }
    if lens.len() != hlit + hdist {
        bail!("code-length overrun");
    }
    let lit = Decoder::from_lengths(&lens[..hlit])?;
    // All-zero distance lengths are legal (no matches); give the decoder a
    // dummy 1-bit code so construction succeeds — it will never be read.
    let dist = if lens[hlit..].iter().all(|&l| l == 0) {
        Decoder::from_lengths(&[1, 1])?
    } else {
        Decoder::from_lengths(&lens[hlit..])?
    };
    Ok((lit, dist))
}

fn read_huffman_block(
    r: &mut LsbReader,
    lit: &Decoder,
    dist: &Decoder,
    out: &mut Vec<u8>,
) -> Result<()> {
    loop {
        let sym = lit.read(r)? as usize;
        match sym {
            0..=255 => out.push(sym as u8),
            256 => return Ok(()),
            257..=285 => {
                let (eb, base) = LEN_TABLE[sym - 257];
                let len = base as usize
                    + r.read_bits(eb).ok_or_else(|| anyhow::anyhow!("eof in len"))? as usize;
                let dsym = dist.read(r)? as usize;
                if dsym >= 30 {
                    bail!("bad distance symbol");
                }
                let (deb, dbase) = DIST_TABLE[dsym];
                let d = dbase as usize
                    + r.read_bits(deb).ok_or_else(|| anyhow::anyhow!("eof in dist"))? as usize;
                if d > out.len() {
                    bail!("distance {d} beyond output ({} bytes)", out.len());
                }
                let start = out.len() - d;
                for k in 0..len {
                    let b = out[start + k];
                    out.push(b);
                }
            }
            _ => bail!("bad literal/length symbol {sym}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check_bytes;

    #[test]
    fn roundtrip_property() {
        check_bytes(21, 60, 5000, |data| {
            decompress(&compress(data, 64)).map(|d| d == data).unwrap_or(false)
        });
    }

    #[test]
    fn empty_input() {
        let c = compress(&[], 16);
        assert_eq!(decompress(&c).unwrap(), Vec::<u8>::new());
    }

    #[test]
    fn length_and_dist_code_tables() {
        assert_eq!(length_code(3), 0);
        assert_eq!(length_code(10), 7);
        assert_eq!(length_code(11), 8);
        assert_eq!(length_code(12), 8);
        assert_eq!(length_code(257), 27);
        assert_eq!(length_code(258), 28);
        assert_eq!(dist_code(1), 0);
        assert_eq!(dist_code(4), 3);
        assert_eq!(dist_code(5), 4);
        assert_eq!(dist_code(24577), 29);
        assert_eq!(dist_code(32768), 29);
    }

    #[test]
    fn compresses_repetitive_data_well() {
        let data: Vec<u8> = b"abcabcabc".iter().cycle().take(10_000).copied().collect();
        let c = compress(&data, 64);
        assert!(c.len() < 200, "repetitive data should crush: {} bytes", c.len());
        assert_eq!(decompress(&c).unwrap(), data);
    }

    #[test]
    fn decodes_fixed_block_stream() {
        // Hand-built fixed-Huffman block containing "Hi".
        let mut w = LsbWriter::new();
        w.write_bits(1, 1); // bfinal
        w.write_bits(1, 2); // fixed
        let mut lens = vec![0u32; 288];
        for (i, l) in lens.iter_mut().enumerate() {
            *l = match i {
                0..=143 => 8,
                144..=255 => 9,
                256..=279 => 7,
                _ => 8,
            };
        }
        let enc = Encoder::from_lengths(&lens);
        enc.write(&mut w, b'H' as usize);
        enc.write(&mut w, b'i' as usize);
        enc.write(&mut w, 256);
        let bytes = w.finish();
        assert_eq!(decompress(&bytes).unwrap(), b"Hi");
    }

    #[test]
    fn rejects_corrupt_streams() {
        let data = b"hello world hello world".to_vec();
        let mut c = compress(&data, 16);
        // Truncation.
        assert!(decompress(&c[..c.len() / 2]).is_err());
        // Bit flip in header region.
        c[0] ^= 0x02;
        let r = decompress(&c);
        if let Ok(d) = r {
            assert_ne!(d, data);
        }
    }

    #[test]
    fn rle_code_lengths_runs() {
        let lens = vec![0u32; 20];
        let syms = rle_code_lengths(&lens);
        assert_eq!(syms, vec![(18, 9)]); // 20 zeros = code 18 with extra 9
        let lens = vec![5, 5, 5, 5, 5, 5, 5, 5];
        let syms = rle_code_lengths(&lens);
        assert_eq!(syms[0], (5, 0)); // literal then repeats
        let total: usize = syms
            .iter()
            .map(|&(s, e)| match s {
                16 => 3 + e as usize,
                17 => 3 + e as usize,
                18 => 11 + e as usize,
                _ => 1,
            })
            .sum();
        assert_eq!(total, 8);
    }
}
