//! Canonical, length-limited Huffman coding (shared by the DEFLATE,
//! bz-style and WebP-style baselines).
//!
//! * Code lengths are computed with the **package-merge** algorithm, which
//!   is optimal under a maximum-length constraint (DEFLATE needs ≤ 15, the
//!   code-length code ≤ 7).
//! * Codes are assigned canonically (ordered by (length, symbol)), the
//!   convention DEFLATE requires, so the decoder can be reconstructed from
//!   lengths alone.

use crate::util::bitio::{LsbReader, LsbWriter};
use anyhow::{bail, Result};

/// Compute optimal length-limited code lengths via package-merge.
///
/// `freqs[i] == 0` ⇒ symbol `i` gets no code (length 0). If only one
/// symbol has nonzero frequency it gets length 1 (DEFLATE requires ≥ 1
/// bit per coded symbol).
pub fn code_lengths(freqs: &[u64], max_len: u32) -> Vec<u32> {
    let n = freqs.len();
    let active: Vec<usize> = (0..n).filter(|&i| freqs[i] > 0).collect();
    let mut lens = vec![0u32; n];
    match active.len() {
        0 => return lens,
        1 => {
            lens[active[0]] = 1;
            return lens;
        }
        _ => {}
    }
    assert!(
        (1u64 << max_len) >= active.len() as u64,
        "max_len {max_len} too small for {} symbols",
        active.len()
    );

    // Package-merge: coins of denominations 2^-1 .. 2^-max_len.
    // Item = (weight, set of symbols it contains — tracked via counts).
    #[derive(Clone)]
    struct Item {
        w: u64,
        syms: Vec<usize>, // indices into `active`
    }
    let mut packages: Vec<Item> = Vec::new();
    for _level in 0..max_len {
        // New coins at this level: one per active symbol.
        let mut items: Vec<Item> = active
            .iter()
            .enumerate()
            .map(|(ai, &s)| Item {
                w: freqs[s],
                syms: vec![ai],
            })
            .collect();
        // Plus packages carried from the previous (deeper) level.
        items.extend(packages.drain(..));
        items.sort_by_key(|it| it.w);
        // Pair adjacent items into packages for the next level.
        packages = items
            .chunks(2)
            .filter(|c| c.len() == 2)
            .map(|c| {
                let mut syms = c[0].syms.clone();
                syms.extend_from_slice(&c[1].syms);
                Item {
                    w: c[0].w + c[1].w,
                    syms,
                }
            })
            .collect();
    }
    // Take the 2(m-1) cheapest items at the top level; each occurrence of
    // a symbol adds one to its code length.
    let mut counts = vec![0u32; active.len()];
    for item in packages.iter().take(active.len() - 1) {
        for &ai in &item.syms {
            counts[ai] += 1;
        }
    }
    for (ai, &s) in active.iter().enumerate() {
        lens[s] = counts[ai];
    }
    debug_assert!(kraft_ok(&lens), "package-merge produced invalid lengths");
    lens
}

/// Kraft inequality check: sum 2^-len <= 1 (== 1 for a complete code).
pub fn kraft_ok(lens: &[u32]) -> bool {
    let mut sum = 0u64;
    let scale = 32;
    for &l in lens {
        if l > 0 {
            sum += 1u64 << (scale - l);
        }
    }
    sum <= 1u64 << scale
}

/// Canonical code assignment from lengths: `codes[i]` is the code for
/// symbol `i`, MSB-first in the low `lens[i]` bits.
pub fn canonical_codes(lens: &[u32]) -> Vec<u32> {
    let max_len = lens.iter().copied().max().unwrap_or(0);
    let mut bl_count = vec![0u32; max_len as usize + 1];
    for &l in lens {
        if l > 0 {
            bl_count[l as usize] += 1;
        }
    }
    let mut next_code = vec![0u32; max_len as usize + 2];
    let mut code = 0u32;
    for bits in 1..=max_len as usize {
        code = (code + bl_count[bits - 1]) << 1;
        next_code[bits] = code;
    }
    lens.iter()
        .map(|&l| {
            if l == 0 {
                0
            } else {
                let c = next_code[l as usize];
                next_code[l as usize] += 1;
                c
            }
        })
        .collect()
}

/// Reverse the low `n` bits of `v` (DEFLATE writes Huffman codes MSB-first
/// into an LSB-first bitstream).
#[inline]
pub fn reverse_bits(v: u32, n: u32) -> u32 {
    if n == 0 {
        return 0;
    }
    v.reverse_bits() >> (32 - n)
}

/// Encoder: symbol → (bit-reversed code, length), ready for an LsbWriter.
#[derive(Debug, Clone)]
pub struct Encoder {
    entries: Vec<(u32, u32)>, // (reversed code, len)
}

impl Encoder {
    pub fn from_lengths(lens: &[u32]) -> Self {
        let codes = canonical_codes(lens);
        Self {
            entries: codes
                .iter()
                .zip(lens.iter())
                .map(|(&c, &l)| (reverse_bits(c, l), l))
                .collect(),
        }
    }

    #[inline]
    pub fn write(&self, w: &mut LsbWriter, sym: usize) {
        let (code, len) = self.entries[sym];
        debug_assert!(len > 0, "writing symbol {sym} with no code");
        w.write_bits(code as u64, len);
    }

    pub fn len_of(&self, sym: usize) -> u32 {
        self.entries[sym].1
    }
}

/// Table-driven canonical decoder (single-level lookup table).
#[derive(Debug, Clone)]
pub struct Decoder {
    /// Lookup on the next `root_bits` (LSB-first) bits → (symbol, len).
    /// For codes longer than `root_bits` (rare) we fall back to a linear
    /// canonical walk.
    table: Vec<(u16, u8)>,
    root_bits: u32,
    max_len: u32,
    /// (first_code, first_index, count) per length for the slow path.
    by_len: Vec<(u32, u32, u32)>,
    /// Symbols ordered canonically ((len, sym)).
    order: Vec<u16>,
}

pub const INVALID_SYM: u16 = u16::MAX;

impl Decoder {
    pub fn from_lengths(lens: &[u32]) -> Result<Self> {
        if lens.len() > u16::MAX as usize {
            bail!("alphabet too large");
        }
        if !kraft_ok(lens) {
            bail!("over-subscribed code lengths");
        }
        let max_len = lens.iter().copied().max().unwrap_or(0);
        if max_len == 0 {
            bail!("empty Huffman code");
        }
        let root_bits = max_len.min(10);
        let codes = canonical_codes(lens);

        let mut table = vec![(INVALID_SYM, 0u8); 1usize << root_bits];
        for (sym, (&code, &len)) in codes.iter().zip(lens.iter()).enumerate() {
            if len == 0 || len > root_bits {
                continue;
            }
            // The decoder peeks LSB-first, so index by reversed code with
            // all possible suffixes.
            let rev = reverse_bits(code, len);
            let step = 1usize << len;
            let mut idx = rev as usize;
            while idx < table.len() {
                table[idx] = (sym as u16, len as u8);
                idx += step;
            }
        }

        // Slow path metadata.
        let mut order: Vec<u16> = (0..lens.len() as u16)
            .filter(|&s| lens[s as usize] > 0)
            .collect();
        order.sort_by_key(|&s| (lens[s as usize], s));
        let mut by_len = Vec::with_capacity(max_len as usize + 1);
        let mut idx = 0u32;
        for l in 1..=max_len {
            let count = order
                .iter()
                .filter(|&&s| lens[s as usize] == l)
                .count() as u32;
            let first_code = if count > 0 {
                codes[order[idx as usize] as usize]
            } else {
                0
            };
            by_len.push((first_code, idx, count));
            idx += count;
        }
        Ok(Self {
            table,
            root_bits,
            max_len,
            by_len,
            order,
        })
    }

    /// Decode one symbol from an LSB-first reader.
    #[inline]
    pub fn read(&self, r: &mut LsbReader) -> Result<u16> {
        let peek = r.peek_bits(self.root_bits) as usize;
        let (sym, len) = self.table[peek];
        if sym != INVALID_SYM {
            if (r.bits_remaining() as u32) < len as u32 {
                bail!("truncated Huffman stream");
            }
            r.consume(len as u32);
            return Ok(sym);
        }
        // Slow path: canonical walk, MSB-first code reconstruction.
        let mut code = 0u32;
        for l in 1..=self.max_len {
            let bit = r
                .read_bits(1)
                .ok_or_else(|| anyhow::anyhow!("truncated Huffman stream"))?;
            code = (code << 1) | bit as u32;
            let (first_code, first_idx, count) = self.by_len[l as usize - 1];
            if count > 0 && code >= first_code && code < first_code + count {
                let sym = self.order[(first_idx + (code - first_code)) as usize];
                return Ok(sym);
            }
        }
        bail!("invalid Huffman code")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn lengths_satisfy_kraft_and_limit() {
        let mut rng = Rng::new(1);
        for _ in 0..50 {
            let n = 2 + rng.below(285) as usize;
            let freqs: Vec<u64> = (0..n)
                .map(|_| if rng.f64() < 0.2 { 0 } else { rng.below(10_000) + 1 })
                .collect();
            if freqs.iter().filter(|&&f| f > 0).count() == 0 {
                continue;
            }
            for max_len in [9u32, 15] {
                if (1u64 << max_len) < n as u64 {
                    continue;
                }
                let lens = code_lengths(&freqs, max_len);
                assert!(kraft_ok(&lens));
                assert!(lens.iter().all(|&l| l <= max_len));
                for (f, l) in freqs.iter().zip(lens.iter()) {
                    assert_eq!(*f > 0, *l > 0, "coded iff nonzero freq");
                }
            }
        }
    }

    #[test]
    fn package_merge_is_near_optimal() {
        // Compare total cost against entropy: must be within 1 bit/symbol.
        let freqs: Vec<u64> = vec![1000, 500, 250, 125, 60, 30, 15, 8, 4, 2, 1, 1];
        let lens = code_lengths(&freqs, 15);
        let total: u64 = freqs.iter().sum();
        let cost: f64 = freqs
            .iter()
            .zip(lens.iter())
            .map(|(&f, &l)| f as f64 * l as f64)
            .sum::<f64>()
            / total as f64;
        let entropy: f64 = freqs
            .iter()
            .map(|&f| {
                let p = f as f64 / total as f64;
                -p * p.log2()
            })
            .sum();
        assert!(cost < entropy + 0.1, "cost {cost} vs entropy {entropy}");
    }

    #[test]
    fn length_limit_binds() {
        // Exponential frequencies force long unlimited codes; the limit
        // must cap them at the cost of slight suboptimality.
        let freqs: Vec<u64> = (0..20).map(|i| 1u64 << i).collect();
        let lens = code_lengths(&freqs, 8);
        assert!(lens.iter().all(|&l| l > 0 && l <= 8));
        assert!(kraft_ok(&lens));
    }

    #[test]
    fn canonical_codes_are_prefix_free() {
        let freqs: Vec<u64> = vec![5, 9, 12, 13, 16, 45, 1, 2];
        let lens = code_lengths(&freqs, 15);
        let codes = canonical_codes(&lens);
        for i in 0..freqs.len() {
            for j in 0..freqs.len() {
                if i == j || lens[i] == 0 || lens[j] == 0 {
                    continue;
                }
                let (li, lj) = (lens[i], lens[j]);
                if li <= lj {
                    let prefix = codes[j] >> (lj - li);
                    assert!(
                        prefix != codes[i],
                        "code {i} is a prefix of code {j}"
                    );
                }
            }
        }
    }

    #[test]
    fn encode_decode_roundtrip() {
        let mut rng = Rng::new(3);
        for trial in 0..20 {
            let n = 2 + rng.below(300) as usize;
            let freqs: Vec<u64> = (0..n).map(|_| rng.below(1000) + 1).collect();
            let lens = code_lengths(&freqs, 15);
            let enc = Encoder::from_lengths(&lens);
            let dec = Decoder::from_lengths(&lens).unwrap();
            let syms: Vec<usize> = (0..2000).map(|_| rng.below(n as u64) as usize).collect();
            let mut w = LsbWriter::new();
            for &s in &syms {
                enc.write(&mut w, s);
            }
            let bytes = w.finish();
            let mut r = LsbReader::new(&bytes);
            for &s in &syms {
                assert_eq!(dec.read(&mut r).unwrap() as usize, s, "trial {trial}");
            }
        }
    }

    #[test]
    fn long_codes_use_slow_path() {
        // Exponentially-growing frequencies force the rare symbols to the
        // 15-bit limit, past the 10-bit root table -> fallback walk.
        let freqs: Vec<u64> = (0..20).map(|i| 1u64 << i).collect();
        let lens = code_lengths(&freqs, 15);
        assert!(lens.iter().any(|&l| l > 10), "want some codes > root_bits: {lens:?}");
        let enc = Encoder::from_lengths(&lens);
        let dec = Decoder::from_lengths(&lens).unwrap();
        let syms: Vec<usize> = (0..20).collect();
        let mut w = LsbWriter::new();
        for &s in &syms {
            enc.write(&mut w, s);
        }
        let bytes = w.finish();
        let mut r = LsbReader::new(&bytes);
        for &s in &syms {
            assert_eq!(dec.read(&mut r).unwrap() as usize, s);
        }
    }

    #[test]
    fn decoder_rejects_bad_lengths() {
        // Over-subscribed: three codes of length 1.
        assert!(Decoder::from_lengths(&[1, 1, 1]).is_err());
        assert!(Decoder::from_lengths(&[0, 0]).is_err());
    }

    #[test]
    fn single_symbol_code() {
        let lens = code_lengths(&[0, 7, 0], 15);
        assert_eq!(lens, vec![0, 1, 0]);
        let enc = Encoder::from_lengths(&lens);
        let dec = Decoder::from_lengths(&lens).unwrap();
        let mut w = LsbWriter::new();
        for _ in 0..5 {
            enc.write(&mut w, 1);
        }
        let bytes = w.finish();
        let mut r = LsbReader::new(&bytes);
        for _ in 0..5 {
            assert_eq!(dec.read(&mut r).unwrap(), 1);
        }
    }
}
