//! Burrows–Wheeler transform (with sentinel index) and move-to-front,
//! the core of the bz2-style baseline.
//!
//! The forward transform sorts suffixes with a prefix-doubling sort
//! (O(n log² n), no external suffix-array crate), treating the input as
//! cyclic rotations via the classic double-string trick.

/// Forward BWT. Returns (last column, primary index).
///
/// Perf (EXPERIMENTS.md §Perf #4): ranks are packed into a single `u64`
/// key (`rank << 32 | rank_at_offset`) computed once per round into a
/// scratch array, so each sort round compares one integer instead of
/// chasing two indirections per comparison.
pub fn bwt_forward(data: &[u8]) -> (Vec<u8>, usize) {
    let n = data.len();
    if n == 0 {
        return (Vec::new(), 0);
    }
    // Sort cyclic rotations via prefix doubling over ranks.
    let mut rank: Vec<u32> = data.iter().map(|&b| b as u32).collect();
    let mut sa: Vec<u32> = (0..n as u32).collect();
    let mut keys = vec![0u64; n];
    let mut tmp = vec![0u32; n];
    let mut k = 1usize;
    while k < n {
        for i in 0..n {
            let j = if i + k >= n { i + k - n } else { i + k };
            keys[i] = ((rank[i] as u64) << 32) | rank[j] as u64;
        }
        sa.sort_unstable_by_key(|&i| keys[i as usize]);
        tmp[sa[0] as usize] = 0;
        for w in 1..n {
            tmp[sa[w] as usize] = tmp[sa[w - 1] as usize]
                + (keys[sa[w] as usize] != keys[sa[w - 1] as usize]) as u32;
        }
        rank.copy_from_slice(&tmp);
        if rank[sa[n - 1] as usize] == n as u32 - 1 {
            break;
        }
        k *= 2;
    }
    let mut out = Vec::with_capacity(n);
    let mut primary = 0usize;
    for (w, &i) in sa.iter().enumerate() {
        let i = i as usize;
        if i == 0 {
            primary = w;
        }
        out.push(data[(i + n - 1) % n]);
    }
    (out, primary)
}

/// Inverse BWT.
pub fn bwt_inverse(last: &[u8], primary: usize) -> Vec<u8> {
    let n = last.len();
    if n == 0 {
        return Vec::new();
    }
    assert!(primary < n, "primary index out of range");
    // Counting sort to build the LF mapping.
    let mut counts = [0usize; 256];
    for &b in last {
        counts[b as usize] += 1;
    }
    let mut starts = [0usize; 256];
    let mut acc = 0;
    for b in 0..256 {
        starts[b] = acc;
        acc += counts[b];
    }
    // next[i] = position in `last` of the successor row.
    let mut next = vec![0usize; n];
    let mut seen = [0usize; 256];
    for (i, &b) in last.iter().enumerate() {
        next[starts[b as usize] + seen[b as usize]] = i;
        seen[b as usize] += 1;
    }
    let mut out = Vec::with_capacity(n);
    let mut p = next[primary];
    for _ in 0..n {
        out.push(last[p]);
        p = next[p];
    }
    // The walk yields the string rotated so that it starts right after the
    // original first character; starting from next[primary] gives the
    // original order.
    out
}

/// Move-to-front encoding.
pub fn mtf_forward(data: &[u8]) -> Vec<u8> {
    let mut table: Vec<u8> = (0..=255).collect();
    data.iter()
        .map(|&b| {
            let pos = table.iter().position(|&t| t == b).unwrap();
            table.remove(pos);
            table.insert(0, b);
            pos as u8
        })
        .collect()
}

/// Move-to-front decoding.
pub fn mtf_inverse(codes: &[u8]) -> Vec<u8> {
    let mut table: Vec<u8> = (0..=255).collect();
    codes
        .iter()
        .map(|&c| {
            let b = table[c as usize];
            table.remove(c as usize);
            table.insert(0, b);
            b
        })
        .collect()
}

/// Zero-run-length encoding over MTF output (bzip2's RUNA/RUNB idea,
/// simplified): runs of 0 are emitted as 0x00 followed by a varint run
/// length; any other byte passes through (offset by nothing — 0 only
/// appears as a run marker).
pub fn zrle_forward(data: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(data.len());
    let mut i = 0;
    while i < data.len() {
        if data[i] == 0 {
            let mut run = 0usize;
            while i < data.len() && data[i] == 0 {
                run += 1;
                i += 1;
            }
            out.push(0);
            // varint
            let mut r = run;
            loop {
                let mut byte = (r & 0x7f) as u8;
                r >>= 7;
                if r > 0 {
                    byte |= 0x80;
                }
                out.push(byte);
                if r == 0 {
                    break;
                }
            }
        } else {
            out.push(data[i]);
            i += 1;
        }
    }
    out
}

pub fn zrle_inverse(data: &[u8]) -> anyhow::Result<Vec<u8>> {
    let mut out = Vec::with_capacity(data.len() * 2);
    let mut i = 0;
    while i < data.len() {
        if data[i] == 0 {
            i += 1;
            let mut run = 0usize;
            let mut shift = 0u32;
            loop {
                if i >= data.len() {
                    anyhow::bail!("truncated zero-run varint");
                }
                let b = data[i];
                i += 1;
                run |= ((b & 0x7f) as usize) << shift;
                shift += 7;
                if b & 0x80 == 0 {
                    break;
                }
                if shift > 35 {
                    anyhow::bail!("zero-run varint too long");
                }
            }
            out.resize(out.len() + run, 0);
        } else {
            out.push(data[i]);
            i += 1;
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check_bytes;

    #[test]
    fn bwt_banana() {
        let (last, primary) = bwt_forward(b"banana");
        assert_eq!(bwt_inverse(&last, primary), b"banana");
        // BWT groups like characters.
        let (last2, _) = bwt_forward(b"mississippi");
        let runs = last2.windows(2).filter(|w| w[0] == w[1]).count();
        assert!(runs >= 3, "BWT should create runs: {last2:?}");
    }

    #[test]
    fn bwt_roundtrip_property() {
        check_bytes(41, 50, 3000, |data| {
            let (last, p) = bwt_forward(data);
            bwt_inverse(&last, p) == data
        });
    }

    #[test]
    fn bwt_handles_periodic_input() {
        // All-equal and periodic strings are the degenerate cases for
        // rotation sorts.
        for data in [vec![7u8; 500], b"abab".repeat(100), vec![0u8; 1]] {
            let (last, p) = bwt_forward(&data);
            assert_eq!(bwt_inverse(&last, p), data);
        }
    }

    #[test]
    fn mtf_roundtrip_and_locality() {
        check_bytes(42, 50, 2000, |data| mtf_inverse(&mtf_forward(data)) == data);
        // Runs become zeros.
        let out = mtf_forward(b"aaaabbbb");
        assert_eq!(&out[1..4], &[0, 0, 0]);
        assert_eq!(&out[5..], &[0, 0, 0]);
    }

    #[test]
    fn zrle_roundtrip_property() {
        check_bytes(43, 50, 3000, |data| {
            zrle_inverse(&zrle_forward(data)).map(|d| d == data).unwrap_or(false)
        });
    }

    #[test]
    fn zrle_compresses_zero_runs() {
        let mut data = vec![0u8; 10_000];
        data.push(5);
        let z = zrle_forward(&data);
        assert!(z.len() < 10, "long zero run should be tiny: {} bytes", z.len());
        assert_eq!(zrle_inverse(&z).unwrap(), data);
    }
}
