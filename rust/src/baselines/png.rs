//! PNG from scratch: real container (signature, IHDR/IDAT/IEND, CRC-32),
//! scanline filters 0–4 with the minimum-sum-of-absolute-differences
//! heuristic, zlib/DEFLATE payload. Grayscale, bit depth 8 or 1 (depth 1
//! for binarized images — that is what makes the paper's PNG number on
//! binarized MNIST meaningful).

use super::gzip::{zlib_compress, zlib_decompress};
use crate::util::crc32;
use anyhow::{bail, Context, Result};

const SIGNATURE: [u8; 8] = [0x89, b'P', b'N', b'G', b'\r', b'\n', 0x1a, b'\n'];

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PngInfo {
    pub width: u32,
    pub height: u32,
    pub bit_depth: u8, // 1 or 8, grayscale
}

fn chunk(out: &mut Vec<u8>, kind: &[u8; 4], body: &[u8]) {
    out.extend_from_slice(&(body.len() as u32).to_be_bytes());
    out.extend_from_slice(kind);
    out.extend_from_slice(body);
    let mut h = crc32::Hasher::new();
    h.update(kind);
    h.update(body);
    out.extend_from_slice(&h.finalize().to_be_bytes());
}

#[inline]
fn paeth(a: i32, b: i32, c: i32) -> i32 {
    let p = a + b - c;
    let (pa, pb, pc) = ((p - a).abs(), (p - b).abs(), (p - c).abs());
    if pa <= pb && pa <= pc {
        a
    } else if pb <= pc {
        b
    } else {
        c
    }
}

/// Pack a row of 0/1 pixels into depth-1 bytes (MSB first).
fn pack_bits(row: &[u8]) -> Vec<u8> {
    let mut out = vec![0u8; row.len().div_ceil(8)];
    for (i, &v) in row.iter().enumerate() {
        if v != 0 {
            out[i / 8] |= 0x80 >> (i % 8);
        }
    }
    out
}

fn unpack_bits(bytes: &[u8], width: usize) -> Vec<u8> {
    (0..width)
        .map(|i| ((bytes[i / 8] >> (7 - i % 8)) & 1) as u8)
        .collect()
}

/// Filter one raw scanline (depth-8) with the chosen filter.
fn apply_filter(filter: u8, row: &[u8], prev: &[u8]) -> Vec<u8> {
    let n = row.len();
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        let x = row[i] as i32;
        let a = if i > 0 { row[i - 1] as i32 } else { 0 };
        let b = prev[i] as i32;
        let c = if i > 0 { prev[i - 1] as i32 } else { 0 };
        let v = match filter {
            0 => x,
            1 => x - a,
            2 => x - b,
            3 => x - (a + b) / 2,
            4 => x - paeth(a, b, c),
            _ => unreachable!(),
        };
        out.push((v & 0xff) as u8);
    }
    out
}

fn unfilter(filter: u8, row: &mut [u8], prev: &[u8]) -> Result<()> {
    let n = row.len();
    for i in 0..n {
        let a = if i > 0 { row[i - 1] as i32 } else { 0 };
        let b = prev[i] as i32;
        let c = if i > 0 { prev[i - 1] as i32 } else { 0 };
        let raw = row[i] as i32;
        let v = match filter {
            0 => raw,
            1 => raw + a,
            2 => raw + b,
            3 => raw + (a + b) / 2,
            4 => raw + paeth(a, b, c),
            _ => bail!("bad filter {filter}"),
        };
        row[i] = (v & 0xff) as u8;
    }
    Ok(())
}

/// Encode a grayscale image (`pixels[y * width + x]`).
///
/// `bit_depth` 1 requires all pixel values ∈ {0, 1}.
pub fn encode(pixels: &[u8], width: usize, height: usize, bit_depth: u8) -> Result<Vec<u8>> {
    if pixels.len() != width * height {
        bail!("pixel buffer size mismatch");
    }
    let mut raw = Vec::new(); // filtered scanline stream
    match bit_depth {
        8 => {
            let mut prev = vec![0u8; width];
            for y in 0..height {
                let row = &pixels[y * width..(y + 1) * width];
                // Heuristic: minimal sum of |signed residual|.
                let (mut best_f, mut best_cost, mut best_row) = (0u8, u64::MAX, Vec::new());
                for f in 0..=4u8 {
                    let cand = apply_filter(f, row, &prev);
                    let cost: u64 = cand
                        .iter()
                        .map(|&v| (v as i8).unsigned_abs() as u64)
                        .sum();
                    if cost < best_cost {
                        best_f = f;
                        best_cost = cost;
                        best_row = cand;
                    }
                }
                raw.push(best_f);
                raw.extend_from_slice(&best_row);
                prev = row.to_vec();
            }
        }
        1 => {
            if pixels.iter().any(|&v| v > 1) {
                bail!("bit depth 1 requires binary pixels");
            }
            let mut prev = vec![0u8; width.div_ceil(8)];
            for y in 0..height {
                let packed = pack_bits(&pixels[y * width..(y + 1) * width]);
                // Depth-1 filtering operates on packed bytes; filter 0
                // (none) and 2 (up) are the useful ones.
                let none_cost: u64 = packed.iter().map(|&v| v.count_ones() as u64).sum();
                let up: Vec<u8> = packed
                    .iter()
                    .zip(prev.iter())
                    .map(|(&x, &b)| x.wrapping_sub(b))
                    .collect();
                let up_cost: u64 = up.iter().map(|&v| v.count_ones() as u64).sum();
                if up_cost < none_cost {
                    raw.push(2);
                    raw.extend_from_slice(&up);
                } else {
                    raw.push(0);
                    raw.extend_from_slice(&packed);
                }
                prev = packed;
            }
        }
        _ => bail!("unsupported bit depth {bit_depth}"),
    }

    let mut out = Vec::new();
    out.extend_from_slice(&SIGNATURE);
    let mut ihdr = Vec::new();
    ihdr.extend_from_slice(&(width as u32).to_be_bytes());
    ihdr.extend_from_slice(&(height as u32).to_be_bytes());
    ihdr.push(bit_depth);
    ihdr.push(0); // grayscale
    ihdr.extend_from_slice(&[0, 0, 0]); // deflate, adaptive, no interlace
    chunk(&mut out, b"IHDR", &ihdr);
    chunk(&mut out, b"IDAT", &zlib_compress(&raw, 128));
    chunk(&mut out, b"IEND", &[]);
    Ok(out)
}

/// Decode a PNG produced by [`encode`] (grayscale, depth 1/8, no
/// interlace). Returns (pixels, info).
pub fn decode(data: &[u8]) -> Result<(Vec<u8>, PngInfo)> {
    if data.len() < 8 || data[0..8] != SIGNATURE {
        bail!("bad PNG signature");
    }
    let mut pos = 8usize;
    let mut info: Option<PngInfo> = None;
    let mut idat = Vec::new();
    loop {
        if pos + 8 > data.len() {
            bail!("truncated chunk header");
        }
        let len = u32::from_be_bytes(data[pos..pos + 4].try_into().unwrap()) as usize;
        let kind: [u8; 4] = data[pos + 4..pos + 8].try_into().unwrap();
        if pos + 8 + len + 4 > data.len() {
            bail!("truncated chunk body");
        }
        let body = &data[pos + 8..pos + 8 + len];
        let want_crc =
            u32::from_be_bytes(data[pos + 8 + len..pos + 12 + len].try_into().unwrap());
        let mut h = crc32::Hasher::new();
        h.update(&kind);
        h.update(body);
        if h.finalize() != want_crc {
            bail!("chunk CRC mismatch ({})", String::from_utf8_lossy(&kind));
        }
        pos += 12 + len;
        match &kind {
            b"IHDR" => {
                if body.len() != 13 {
                    bail!("bad IHDR");
                }
                let width = u32::from_be_bytes(body[0..4].try_into().unwrap());
                let height = u32::from_be_bytes(body[4..8].try_into().unwrap());
                let bit_depth = body[8];
                if body[9] != 0 {
                    bail!("only grayscale supported");
                }
                if body[12] != 0 {
                    bail!("interlace unsupported");
                }
                info = Some(PngInfo {
                    width,
                    height,
                    bit_depth,
                });
            }
            b"IDAT" => idat.extend_from_slice(body),
            b"IEND" => break,
            _ => {} // ignore ancillary
        }
    }
    let info = info.context("missing IHDR")?;
    let raw = zlib_decompress(&idat)?;
    let (w, h) = (info.width as usize, info.height as usize);
    let line = match info.bit_depth {
        8 => w,
        1 => w.div_ceil(8),
        d => bail!("unsupported bit depth {d}"),
    };
    if raw.len() != h * (line + 1) {
        bail!("scanline stream size mismatch");
    }
    let mut pixels = Vec::with_capacity(w * h);
    let mut prev = vec![0u8; line];
    for y in 0..h {
        let filter = raw[y * (line + 1)];
        let mut row = raw[y * (line + 1) + 1..(y + 1) * (line + 1)].to_vec();
        unfilter(filter, &mut row, &prev)?;
        match info.bit_depth {
            8 => pixels.extend_from_slice(&row),
            1 => pixels.extend_from_slice(&unpack_bits(&row, w)),
            _ => unreachable!(),
        }
        prev = row;
    }
    Ok((pixels, info))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::util::rng::Rng;

    #[test]
    fn roundtrip_gray8() {
        let ds = synth::digits(8, 3);
        for img in &ds.images {
            let png = encode(img, 28, 28, 8).unwrap();
            let (pix, info) = decode(&png).unwrap();
            assert_eq!(info.bit_depth, 8);
            assert_eq!(pix, *img);
        }
    }

    #[test]
    fn roundtrip_gray1() {
        let ds = synth::binarize(&synth::digits(8, 4), 5);
        for img in &ds.images {
            let png = encode(img, 28, 28, 1).unwrap();
            let (pix, info) = decode(&png).unwrap();
            assert_eq!(info.bit_depth, 1);
            assert_eq!(pix, *img);
        }
    }

    #[test]
    fn roundtrip_random_noise() {
        let mut rng = Rng::new(6);
        let img: Vec<u8> = (0..64 * 64).map(|_| rng.next_u32() as u8).collect();
        let png = encode(&img, 64, 64, 8).unwrap();
        let (pix, _) = decode(&png).unwrap();
        assert_eq!(pix, img);
    }

    #[test]
    fn filters_help_on_smooth_images() {
        // A gradient image should compress far better than noise thanks to
        // the filters.
        let w = 64;
        let img: Vec<u8> = (0..w * w).map(|i| ((i % w) + (i / w)) as u8).collect();
        let png = encode(&img, w, w, 8).unwrap();
        assert!(
            png.len() < w * w / 4,
            "gradient should compress: {} bytes",
            png.len()
        );
    }

    #[test]
    fn rejects_corruption_and_misuse() {
        let img = vec![0u8; 16];
        let png = encode(&img, 4, 4, 8).unwrap();
        let mut bad = png.clone();
        let n = bad.len();
        bad[n - 7] ^= 0xff; // corrupt IEND CRC region
        assert!(decode(&bad).is_err());
        assert!(decode(&png[..20]).is_err());
        assert!(encode(&img, 3, 4, 8).is_err()); // size mismatch
        assert!(encode(&[2, 0, 0, 0], 2, 2, 1).is_err()); // non-binary depth 1
    }

    #[test]
    fn non_multiple_of_8_width_depth1() {
        let w = 13;
        let h = 5;
        let mut rng = Rng::new(8);
        let img: Vec<u8> = (0..w * h).map(|_| (rng.f64() < 0.3) as u8).collect();
        let png = encode(&img, w, h, 1).unwrap();
        let (pix, _) = decode(&png).unwrap();
        assert_eq!(pix, img);
    }
}
