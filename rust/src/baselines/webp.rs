//! WebP-lossless-**style** codec (simplified VP8L; see DESIGN.md §5).
//!
//! VP8L's grayscale-relevant core is (a) a *spatial predictor transform*
//! chosen per tile from a menu of predictors, followed by (b) LZ77 +
//! canonical-Huffman entropy coding of the residuals. We implement exactly
//! that structure: 8×8 tiles, 6 predictors (black, left, top, top-left,
//! average, clamped-gradient), tile indices + residual plane entropy-coded
//! with our DEFLATE. Omitted VP8L features (color cache, meta-Huffman,
//! cross-color) don't apply to grayscale. Results are labelled
//! "WebP-style" in all tables.

use super::deflate;
use anyhow::{bail, Result};

pub const MAGIC: &[u8; 4] = b"WPL1";
const TILE: usize = 8;
const N_PRED: u8 = 6;

#[inline]
fn clamp_u8(v: i32) -> u8 {
    v.clamp(0, 255) as u8
}

/// Predict pixel (x, y) from already-decoded neighbours.
#[inline]
fn predict(pred: u8, img: &[u8], w: usize, x: usize, y: usize) -> u8 {
    let l = if x > 0 { img[y * w + x - 1] as i32 } else { 0 };
    let t = if y > 0 { img[(y - 1) * w + x] as i32 } else { 0 };
    let tl = if x > 0 && y > 0 {
        img[(y - 1) * w + x - 1] as i32
    } else {
        0
    };
    match pred {
        0 => 0,                                  // black
        1 => l as u8,                            // left
        2 => t as u8,                            // top
        3 => tl as u8,                           // top-left
        4 => ((l + t) / 2) as u8,                // average
        5 => clamp_u8(l + t - tl),               // clamped gradient
        _ => unreachable!(),
    }
}

fn tiles_dims(w: usize, h: usize) -> (usize, usize) {
    (w.div_ceil(TILE), h.div_ceil(TILE))
}

/// Encode a grayscale image.
pub fn encode(pixels: &[u8], w: usize, h: usize) -> Result<Vec<u8>> {
    if pixels.len() != w * h {
        bail!("pixel buffer size mismatch");
    }
    let (tw, th) = tiles_dims(w, h);
    // Choose the best predictor per tile by SAD (causal neighbours come
    // from the *original* image, which the decoder reconstructs in raster
    // order, so predictions match).
    let mut tile_pred = vec![0u8; tw * th];
    for ty in 0..th {
        for tx in 0..tw {
            let (mut best_p, mut best_cost) = (0u8, u64::MAX);
            for p in 0..N_PRED {
                let mut cost = 0u64;
                for y in (ty * TILE)..((ty + 1) * TILE).min(h) {
                    for x in (tx * TILE)..((tx + 1) * TILE).min(w) {
                        let pr = predict(p, pixels, w, x, y) as i32;
                        let d = pixels[y * w + x] as i32 - pr;
                        // Residuals are coded mod 256; cost models the
                        // entropy-friendliness of small magnitudes.
                        cost += d.unsigned_abs().min((256 - d.abs()) as u32) as u64;
                    }
                }
                if cost < best_cost {
                    best_cost = cost;
                    best_p = p;
                }
            }
            tile_pred[ty * tw + tx] = best_p;
        }
    }
    // Residual plane in raster order.
    let mut residuals = Vec::with_capacity(w * h);
    for y in 0..h {
        for x in 0..w {
            let p = tile_pred[(y / TILE) * tw + (x / TILE)];
            let pr = predict(p, pixels, w, x, y);
            residuals.push(pixels[y * w + x].wrapping_sub(pr));
        }
    }
    let mut body = Vec::with_capacity(tile_pred.len() + residuals.len());
    body.extend_from_slice(&tile_pred);
    body.extend_from_slice(&residuals);
    let coded = deflate::compress(&body, 128);

    let mut out = Vec::new();
    out.extend_from_slice(MAGIC);
    out.extend_from_slice(&(w as u32).to_le_bytes());
    out.extend_from_slice(&(h as u32).to_le_bytes());
    out.extend_from_slice(&coded);
    Ok(out)
}

/// Decode. Returns (pixels, width, height).
pub fn decode(data: &[u8]) -> Result<(Vec<u8>, usize, usize)> {
    if data.len() < 12 || &data[0..4] != MAGIC {
        bail!("bad WPL1 header");
    }
    let w = u32::from_le_bytes(data[4..8].try_into().unwrap()) as usize;
    let h = u32::from_le_bytes(data[8..12].try_into().unwrap()) as usize;
    let body = deflate::decompress(&data[12..])?;
    let (tw, th) = tiles_dims(w, h);
    if body.len() != tw * th + w * h {
        bail!("payload size mismatch");
    }
    let (tile_pred, residuals) = body.split_at(tw * th);
    if tile_pred.iter().any(|&p| p >= N_PRED) {
        bail!("bad predictor index");
    }
    let mut img = vec![0u8; w * h];
    for y in 0..h {
        for x in 0..w {
            let p = tile_pred[(y / TILE) * tw + (x / TILE)];
            let pr = predict(p, &img, w, x, y);
            img[y * w + x] = residuals[y * w + x].wrapping_add(pr);
        }
    }
    Ok((img, w, h))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::util::rng::Rng;

    #[test]
    fn roundtrip_digits() {
        let ds = synth::digits(6, 7);
        for img in &ds.images {
            let c = encode(img, 28, 28).unwrap();
            let (out, w, h) = decode(&c).unwrap();
            assert_eq!((w, h), (28, 28));
            assert_eq!(out, *img);
        }
    }

    #[test]
    fn roundtrip_natural_and_noise() {
        let ds = synth::natural(3, 64, 9);
        for img in &ds.images {
            let c = encode(img, 64, 64).unwrap();
            assert_eq!(decode(&c).unwrap().0, *img);
        }
        let mut rng = Rng::new(10);
        let noise: Vec<u8> = (0..40 * 56).map(|_| rng.next_u32() as u8).collect();
        let c = encode(&noise, 40, 56).unwrap();
        assert_eq!(decode(&c).unwrap().0, noise);
    }

    #[test]
    fn predictors_beat_plain_deflate_on_smooth_images() {
        let ds = synth::natural(1, 64, 11);
        let img = &ds.images[0];
        let ours = encode(img, 64, 64).unwrap().len();
        let plain = deflate::compress(img, 128).len();
        assert!(
            ours < plain,
            "predictor transform should help on smooth data: {ours} vs {plain}"
        );
    }

    #[test]
    fn non_tile_multiple_dims() {
        let mut rng = Rng::new(12);
        let (w, h) = (13, 21);
        let img: Vec<u8> = (0..w * h).map(|_| (rng.below(64) + 64) as u8).collect();
        let c = encode(&img, w, h).unwrap();
        assert_eq!(decode(&c).unwrap().0, img);
    }

    #[test]
    fn rejects_corruption() {
        let img = vec![128u8; 28 * 28];
        let c = encode(&img, 28, 28).unwrap();
        assert!(decode(&c[..8]).is_err());
        let mut bad = c.clone();
        bad[0] = b'Z';
        assert!(decode(&bad).is_err());
    }
}
