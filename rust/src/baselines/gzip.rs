//! gzip (RFC 1952) and zlib (RFC 1950) containers around our DEFLATE.

use super::deflate;
use crate::util::crc32;
use anyhow::{bail, Context, Result};

/// Adler-32 (zlib checksum).
pub fn adler32(data: &[u8]) -> u32 {
    const MOD: u32 = 65521;
    let (mut a, mut b) = (1u32, 0u32);
    for chunk in data.chunks(5552) {
        for &x in chunk {
            a += x as u32;
            b += a;
        }
        a %= MOD;
        b %= MOD;
    }
    (b << 16) | a
}

/// gzip-compress `data`.
pub fn gzip_compress(data: &[u8], max_chain: usize) -> Vec<u8> {
    let mut out = vec![
        0x1f, 0x8b, // magic
        0x08, // deflate
        0x00, // no flags
        0, 0, 0, 0, // mtime
        0x00, // XFL
        0xff, // OS unknown
    ];
    out.extend_from_slice(&deflate::compress(data, max_chain));
    out.extend_from_slice(&crc32::hash(data).to_le_bytes());
    out.extend_from_slice(&(data.len() as u32).to_le_bytes());
    out
}

/// Decompress a gzip stream (checks CRC and size).
pub fn gzip_decompress(data: &[u8]) -> Result<Vec<u8>> {
    if data.len() < 18 {
        bail!("gzip too short");
    }
    if data[0] != 0x1f || data[1] != 0x8b {
        bail!("bad gzip magic");
    }
    if data[2] != 0x08 {
        bail!("unsupported compression method {}", data[2]);
    }
    let flg = data[3];
    let mut pos = 10usize;
    if flg & 0x04 != 0 {
        // FEXTRA
        let xlen = u16::from_le_bytes([data[pos], data[pos + 1]]) as usize;
        pos += 2 + xlen;
    }
    if flg & 0x08 != 0 {
        // FNAME
        pos += data[pos..]
            .iter()
            .position(|&b| b == 0)
            .context("unterminated FNAME")?
            + 1;
    }
    if flg & 0x10 != 0 {
        // FCOMMENT
        pos += data[pos..]
            .iter()
            .position(|&b| b == 0)
            .context("unterminated FCOMMENT")?
            + 1;
    }
    if flg & 0x02 != 0 {
        pos += 2; // FHCRC
    }
    if pos + 8 > data.len() {
        bail!("gzip truncated");
    }
    let body = &data[pos..data.len() - 8];
    let out = deflate::decompress(body)?;
    let crc = u32::from_le_bytes(data[data.len() - 8..data.len() - 4].try_into().unwrap());
    let isize_ = u32::from_le_bytes(data[data.len() - 4..].try_into().unwrap());
    if crc32::hash(&out) != crc {
        bail!("gzip CRC mismatch");
    }
    if out.len() as u32 != isize_ {
        bail!("gzip ISIZE mismatch");
    }
    Ok(out)
}

/// zlib-wrap our DEFLATE (PNG uses this).
pub fn zlib_compress(data: &[u8], max_chain: usize) -> Vec<u8> {
    let mut out = vec![0x78, 0x9c]; // CM=8 CINFO=7, check bits, no dict
    out.extend_from_slice(&deflate::compress(data, max_chain));
    out.extend_from_slice(&adler32(data).to_be_bytes());
    out
}

pub fn zlib_decompress(data: &[u8]) -> Result<Vec<u8>> {
    if data.len() < 6 {
        bail!("zlib too short");
    }
    let cmf = data[0];
    let flg = data[1];
    if cmf & 0x0f != 8 {
        bail!("unsupported zlib method");
    }
    if ((cmf as u16) << 8 | flg as u16) % 31 != 0 {
        bail!("zlib header check failed");
    }
    if flg & 0x20 != 0 {
        bail!("preset dictionary unsupported");
    }
    let body = &data[2..data.len() - 4];
    let out = deflate::decompress(body)?;
    let want = u32::from_be_bytes(data[data.len() - 4..].try_into().unwrap());
    if adler32(&out) != want {
        bail!("adler32 mismatch");
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check_bytes;

    #[test]
    fn adler32_reference_values() {
        assert_eq!(adler32(b""), 1);
        assert_eq!(adler32(b"Wikipedia"), 0x11E60398);
    }

    #[test]
    fn gzip_roundtrip_property() {
        check_bytes(31, 40, 4000, |data| {
            gzip_decompress(&gzip_compress(data, 64))
                .map(|d| d == data)
                .unwrap_or(false)
        });
    }

    #[test]
    fn zlib_roundtrip_property() {
        check_bytes(32, 40, 4000, |data| {
            zlib_decompress(&zlib_compress(data, 64))
                .map(|d| d == data)
                .unwrap_or(false)
        });
    }

    #[test]
    fn gzip_detects_corruption() {
        let data = b"some data that we compress".repeat(10);
        let mut c = gzip_compress(&data, 64);
        let n = c.len();
        c[n - 6] ^= 0xff; // corrupt CRC
        assert!(gzip_decompress(&c).is_err());
    }

    // Requires the real flate2 crate, which is not vendored offline.
    #[cfg(feature = "external-codecs")]
    #[test]
    fn interop_with_flate2() {
        // Our gzip must be readable by flate2, and vice versa.
        let data: Vec<u8> = (0..5000u32).map(|i| (i * 7 % 251) as u8).collect();

        // ours -> flate2
        let ours = gzip_compress(&data, 64);
        let mut dec = flate2::read::GzDecoder::new(&ours[..]);
        let mut out = Vec::new();
        std::io::Read::read_to_end(&mut dec, &mut out).expect("flate2 reads our gzip");
        assert_eq!(out, data);

        // flate2 -> ours
        let mut enc = flate2::write::GzEncoder::new(Vec::new(), flate2::Compression::default());
        std::io::Write::write_all(&mut enc, &data).unwrap();
        let theirs = enc.finish().unwrap();
        assert_eq!(gzip_decompress(&theirs).unwrap(), data);
    }
}
