//! PJRT runtime bridge: load AOT-lowered HLO text artifacts and execute
//! them on the CPU PJRT client from the Rust hot path.
//!
//! Pattern (see `/opt/xla-example/load_hlo.rs`): `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`. The
//! artifacts are lowered with `return_tuple=True`, so every output is a
//! tuple literal that we decompose.
//!
//! One [`Engine`] owns the client plus a cache of compiled executables,
//! keyed by artifact name — the coordinator compiles each (model, batch
//! size) variant once at startup and reuses it for every request.
//!
//! The `xla` crate is not available in this offline workspace, so the
//! real engine is gated behind the `xla` cargo feature. Without it,
//! [`Engine`] is a stub whose constructor fails with a clear error;
//! everything that *probes* the runtime ([`artifacts_available`],
//! [`load_config`], [`Tensor`]) works unconditionally, and the pure-Rust
//! [`crate::model::vae::NativeVae`] backend carries the full test suite.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

/// Dense f32 tensor moved across the PJRT boundary.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub dims: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(dims: Vec<usize>, data: Vec<f32>) -> Self {
        debug_assert_eq!(dims.iter().product::<usize>(), data.len());
        Self { dims, data }
    }

    /// Row `i` along the leading dimension.
    pub fn row(&self, i: usize) -> &[f32] {
        let stride: usize = self.dims[1..].iter().product();
        &self.data[i * stride..(i + 1) * stride]
    }
}

#[cfg(feature = "xla")]
mod engine_impl {
    use std::collections::HashMap;
    use std::path::{Path, PathBuf};
    use std::sync::Mutex;

    use anyhow::{anyhow, bail, Result};

    use super::Tensor;

    /// A loaded-and-compiled HLO artifact.
    pub struct Executable {
        exe: xla::PjRtLoadedExecutable,
    }

    /// PJRT CPU engine with an executable cache.
    pub struct Engine {
        client: xla::PjRtClient,
        execs: Mutex<HashMap<String, Executable>>,
        artifact_dir: PathBuf,
    }

    impl Engine {
        /// Create a CPU engine rooted at an artifact directory.
        pub fn cpu(artifact_dir: impl AsRef<Path>) -> Result<Self> {
            let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT client: {e:?}"))?;
            Ok(Self {
                client,
                execs: Mutex::new(HashMap::new()),
                artifact_dir: artifact_dir.as_ref().to_path_buf(),
            })
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        pub fn artifact_dir(&self) -> &Path {
            &self.artifact_dir
        }

        /// Load + compile an HLO text artifact (idempotent; cached by `name`).
        pub fn load(&self, name: &str) -> Result<()> {
            let mut execs = self.execs.lock().unwrap();
            if execs.contains_key(name) {
                return Ok(());
            }
            let path = self.artifact_dir.join(name);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )
            .map_err(|e| anyhow!("parse HLO text {}: {e:?}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compile {}: {e:?}", path.display()))?;
            execs.insert(name.to_string(), Executable { exe });
            Ok(())
        }

        /// Execute artifact `name` on f32 inputs; returns all outputs of the
        /// result tuple as dense f32 tensors.
        pub fn run(&self, name: &str, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
            let execs = self.execs.lock().unwrap();
            let exec = execs
                .get(name)
                .ok_or_else(|| anyhow!("artifact '{name}' not loaded"))?;
            let literals: Vec<xla::Literal> = inputs
                .iter()
                .map(|t| {
                    let dims: Vec<i64> = t.dims.iter().map(|&d| d as i64).collect();
                    xla::Literal::vec1(&t.data)
                        .reshape(&dims)
                        .map_err(|e| anyhow!("reshape input: {e:?}"))
                })
                .collect::<Result<_>>()?;
            let result = exec
                .exe
                .execute::<xla::Literal>(&literals)
                .map_err(|e| anyhow!("execute {name}: {e:?}"))?;
            let out = result
                .first()
                .and_then(|d| d.first())
                .ok_or_else(|| anyhow!("no output buffers from {name}"))?
                .to_literal_sync()
                .map_err(|e| anyhow!("fetch output: {e:?}"))?;
            let parts = out
                .to_tuple()
                .map_err(|e| anyhow!("decompose output tuple: {e:?}"))?;
            parts
                .into_iter()
                .map(|lit| {
                    let shape = lit
                        .array_shape()
                        .map_err(|e| anyhow!("output shape: {e:?}"))?;
                    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
                    let data = lit
                        .to_vec::<f32>()
                        .map_err(|e| anyhow!("output data: {e:?}"))?;
                    if data.len() != dims.iter().product::<usize>() {
                        bail!("output size mismatch: {} vs {:?}", data.len(), dims);
                    }
                    Ok(Tensor { dims, data })
                })
                .collect()
        }

        /// Names currently compiled.
        pub fn loaded(&self) -> Vec<String> {
            self.execs.lock().unwrap().keys().cloned().collect()
        }
    }
}

#[cfg(not(feature = "xla"))]
mod engine_impl {
    use std::path::{Path, PathBuf};

    use anyhow::{bail, Result};

    use super::Tensor;

    /// Stub PJRT engine: the `xla` crate is not built into this binary.
    /// Construction fails, so no caller can reach `load`/`run`; the
    /// methods exist (and bail) to keep the API identical to the real
    /// engine for code that is generic over the runtime.
    pub struct Engine {
        artifact_dir: PathBuf,
    }

    impl Engine {
        pub fn cpu(_artifact_dir: impl AsRef<Path>) -> Result<Self> {
            bail!(
                "PJRT runtime not built: this binary was compiled without the \
                 `xla` feature; use the native backend (--native) instead"
            )
        }

        pub fn platform(&self) -> String {
            "unavailable".to_string()
        }

        pub fn artifact_dir(&self) -> &Path {
            &self.artifact_dir
        }

        pub fn load(&self, name: &str) -> Result<()> {
            bail!("PJRT runtime not built (`xla` feature off): cannot load '{name}'")
        }

        pub fn run(&self, name: &str, _inputs: &[Tensor]) -> Result<Vec<Tensor>> {
            bail!("PJRT runtime not built (`xla` feature off): cannot run '{name}'")
        }

        pub fn loaded(&self) -> Vec<String> {
            Vec::new()
        }
    }
}

pub use engine_impl::Engine;

/// Convenience: read `artifacts/model_config.json`.
pub fn load_config(artifact_dir: impl AsRef<Path>) -> Result<crate::util::json::Json> {
    let p = artifact_dir.as_ref().join("model_config.json");
    let text = std::fs::read_to_string(&p)
        .with_context(|| format!("reading {} (run `make artifacts`)", p.display()))?;
    crate::util::json::Json::parse(&text).map_err(|e| anyhow!("{e}"))
}

/// True if the artifact bundle exists (tests use this to self-skip).
pub fn artifacts_available(artifact_dir: impl AsRef<Path>) -> bool {
    artifact_dir.as_ref().join("model_config.json").exists()
}

/// Default artifact directory: `$BBANS_ARTIFACTS` or `./artifacts`.
pub fn default_artifact_dir() -> PathBuf {
    std::env::var_os("BBANS_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}
