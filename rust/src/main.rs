//! `bbans` — command-line front end for the BB-ANS compression system.
//!
//! Subcommands:
//!   info                         show artifact/model info
//!   compress   -m MODEL -i IDX -o FILE [-n N] [--native] [--latent-bits B]
//!   decompress -i FILE -o IDX [--native]
//!   serve      [--bind ADDR] [--native] [--max-jobs J] [--window-ms W]
//!   client     --addr ADDR --stats
//!
//! Arg parsing is hand-rolled (clap is unavailable offline).

use std::collections::VecDeque;
use std::path::PathBuf;

use anyhow::{anyhow, bail, Context, Result};

use bbans::bbans::container::{Container, ParallelContainer, MAGIC_PARALLEL};
use bbans::bbans::{BbAnsConfig, VaeCodec};
use bbans::coordinator::{Client, ModelService, Server, ServiceParams};
use bbans::data;
use bbans::model::vae::load_native;
use bbans::runtime::{default_artifact_dir, load_config};

struct Args {
    positional: Vec<String>,
    flags: std::collections::HashMap<String, String>,
    switches: std::collections::HashSet<String>,
}

fn parse_args(argv: &[String]) -> Args {
    let mut a = Args {
        positional: Vec::new(),
        flags: Default::default(),
        switches: Default::default(),
    };
    let mut q: VecDeque<_> = argv.iter().cloned().collect();
    while let Some(arg) = q.pop_front() {
        if let Some(name) = arg.strip_prefix("--") {
            if let Some((k, v)) = name.split_once('=') {
                a.flags.insert(k.to_string(), v.to_string());
            } else if q.front().map(|n| !n.starts_with('-')).unwrap_or(false) && !is_switch(name) {
                a.flags.insert(name.to_string(), q.pop_front().unwrap());
            } else {
                a.switches.insert(name.to_string());
            }
        } else if let Some(short) = arg.strip_prefix('-') {
            let name = match short {
                "m" => "model",
                "i" => "input",
                "o" => "output",
                "n" => "count",
                other => other,
            };
            if let Some(v) = q.pop_front() {
                a.flags.insert(name.to_string(), v);
            }
        } else {
            a.positional.push(arg);
        }
    }
    a
}

fn is_switch(name: &str) -> bool {
    matches!(name, "native" | "stats" | "binarized" | "help")
}

fn usage() -> ! {
    eprintln!(
        "usage: bbans <info|compress|decompress|serve|client> [args]\n\
         \n\
         bbans info\n\
         bbans compress   -m bin|full -i images.idx -o out.bbc [-n N] [--native] [--chunks K]\n\
         bbans decompress -i in.bbc -o out.idx [--native]\n\
         bbans serve      [--bind 127.0.0.1:7878] [--native] [--max-jobs 16] [--window-ms 2]\n\
         bbans client     --addr HOST:PORT --stats\n\
         \n\
         --chunks K > 1 encodes K independent chains on K threads (native\n\
         backend; produces a BBC2 chunk-parallel container).\n\
         \n\
         Artifacts default to ./artifacts ($BBANS_ARTIFACTS overrides)."
    );
    std::process::exit(2)
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        usage();
    }
    let cmd = argv[0].clone();
    let args = parse_args(&argv[1..]);
    let result = match cmd.as_str() {
        "info" => cmd_info(),
        "compress" => cmd_compress(&args),
        "decompress" => cmd_decompress(&args),
        "serve" => cmd_serve(&args),
        "client" => cmd_client(&args),
        _ => usage(),
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn service(args: &Args) -> ModelService {
    let params = ServiceParams {
        max_jobs: args
            .flags
            .get("max-jobs")
            .and_then(|v| v.parse().ok())
            .unwrap_or(16),
        batch_window: std::time::Duration::from_millis(
            args.flags
                .get("window-ms")
                .and_then(|v| v.parse().ok())
                .unwrap_or(2),
        ),
        bbans: bbans_config(args),
    };
    ModelService::spawn(
        default_artifact_dir(),
        !args.switches.contains("native"),
        params,
    )
}

fn bbans_config(args: &Args) -> BbAnsConfig {
    let mut cfg = BbAnsConfig::default();
    if let Some(b) = args.flags.get("latent-bits").and_then(|v| v.parse().ok()) {
        cfg.latent_bits = b;
    }
    if let Some(p) = args.flags.get("pixel-prec").and_then(|v| v.parse().ok()) {
        cfg.pixel_prec = p;
    }
    cfg
}

fn cmd_info() -> Result<()> {
    let dir = default_artifact_dir();
    let config = load_config(&dir)?;
    println!("artifact dir : {}", dir.display());
    println!(
        "pixels       : {}",
        config
            .req("pixels")
            .map_err(|e| anyhow!("{e}"))?
            .as_u64()
            .unwrap()
    );
    if let Some(bbans::util::json::Json::Obj(models)) = config.get("models") {
        for (name, m) in models {
            println!(
                "model '{name}': latent={} hidden={} likelihood={} test-ELBO={:.4} bits/dim",
                m.get("latent_dim").and_then(|v| v.as_u64()).unwrap_or(0),
                m.get("hidden").and_then(|v| v.as_u64()).unwrap_or(0),
                m.get("likelihood").and_then(|v| v.as_str()).unwrap_or("?"),
                m.get("test_elbo_bpd")
                    .and_then(|v| v.as_f64())
                    .unwrap_or(f64::NAN),
            );
        }
    }
    Ok(())
}

fn cmd_compress(args: &Args) -> Result<()> {
    let model = args.flags.get("model").context("need -m MODEL")?.clone();
    let input = PathBuf::from(args.flags.get("input").context("need -i IDX")?);
    let output = PathBuf::from(args.flags.get("output").context("need -o FILE")?);
    let ds = data::load_idx_images(&input)?;
    let n = args
        .flags
        .get("count")
        .and_then(|v| v.parse().ok())
        .unwrap_or(ds.len());
    let (rows, cols) = (ds.rows, ds.cols);
    let images: Vec<Vec<u8>> = ds.images.into_iter().take(n).collect();
    let raw_bytes = images.len() * rows * cols;

    let chunks: usize = match args.flags.get("chunks") {
        Some(v) => v
            .parse()
            .map_err(|_| anyhow!("invalid --chunks value '{v}' (want a positive integer)"))?,
        None => 1,
    };
    if chunks > 1 {
        // Chunk-parallel fast path: independent chains on threads, native
        // backend (the PJRT handles are not Sync; it parallelizes through
        // the serving batcher instead).
        let backend = load_native(default_artifact_dir(), &model)?;
        let codec = VaeCodec::new(&backend, bbans_config(args))?;
        let t = std::time::Instant::now();
        let container = ParallelContainer::encode_with(&codec, &images, chunks)?;
        let dt = t.elapsed();
        let bytes = container.to_bytes();
        std::fs::write(&output, &bytes)?;
        let n_images = container.num_images();
        let bpd = bytes.len() as f64 * 8.0 / (n_images as f64 * container.pixels as f64);
        println!(
            "compressed {n_images} images in {} chunks: {raw_bytes} -> {} bytes \
             ({bpd:.4} bits/dim) in {:.2}s ({:.1} img/s)",
            container.chunks.len(),
            bytes.len(),
            dt.as_secs_f64(),
            n_images as f64 / dt.as_secs_f64(),
        );
        return Ok(());
    }

    let svc = service(args);
    let h = svc.handle();
    let t = std::time::Instant::now();
    let container = h.compress(&model, images)?;
    let dt = t.elapsed();
    std::fs::write(&output, &container)?;
    let parsed = Container::from_bytes(&container)?;
    println!(
        "compressed {} images: {} -> {} bytes ({:.4} bits/dim) in {:.2}s ({:.1} img/s)",
        parsed.num_images,
        raw_bytes,
        container.len(),
        parsed.bits_per_dim(),
        dt.as_secs_f64(),
        parsed.num_images as f64 / dt.as_secs_f64(),
    );
    svc.shutdown();
    Ok(())
}

fn cmd_decompress(args: &Args) -> Result<()> {
    let input = PathBuf::from(args.flags.get("input").context("need -i FILE")?);
    let output = PathBuf::from(args.flags.get("output").context("need -o IDX")?);
    let container = std::fs::read(&input)?;

    if container.len() >= 4 && &container[0..4] == MAGIC_PARALLEL {
        // Chunk-parallel container: decode chunks on threads with the
        // native backend named in the header.
        let pc = ParallelContainer::from_bytes(&container)?;
        let backend = load_native(default_artifact_dir(), &pc.model)?;
        if pc.backend_id != backend.backend_id() {
            bail!(
                "container encoded with backend '{}', local backend is '{}'",
                pc.backend_id,
                backend.backend_id()
            );
        }
        let codec = VaeCodec::new(&backend, pc.cfg)?;
        let t = std::time::Instant::now();
        let images = pc.decode_with(&codec)?;
        let dt = t.elapsed();
        let n = write_square_idx(images, &output)?;
        println!(
            "decompressed {n} images ({} chunks) in {:.2}s ({:.1} img/s) -> {}",
            pc.chunks.len(),
            dt.as_secs_f64(),
            n as f64 / dt.as_secs_f64(),
            output.display()
        );
        return Ok(());
    }

    let svc = service(args);
    let h = svc.handle();
    let t = std::time::Instant::now();
    let images = h.decompress(container)?;
    let dt = t.elapsed();
    let n = write_square_idx(images, &output)?;
    println!(
        "decompressed {n} images in {:.2}s ({:.1} img/s) -> {}",
        dt.as_secs_f64(),
        n as f64 / dt.as_secs_f64(),
        output.display()
    );
    svc.shutdown();
    Ok(())
}

/// Write decoded images as a square-image IDX file; returns the count.
fn write_square_idx(images: Vec<Vec<u8>>, output: &std::path::Path) -> Result<usize> {
    let n = images.len();
    let side = (images.first().map(|i| i.len()).unwrap_or(0) as f64).sqrt() as usize;
    let ds = data::Dataset {
        rows: side,
        cols: side,
        images,
    };
    std::fs::write(output, data::write_idx_images(&ds))?;
    Ok(n)
}

fn cmd_serve(args: &Args) -> Result<()> {
    let bind = args
        .flags
        .get("bind")
        .cloned()
        .unwrap_or_else(|| "127.0.0.1:7878".to_string());
    let svc = service(args);
    let server = Server::start(&bind, svc.handle())?;
    println!("bbans serving on {}", server.addr);
    println!("press ctrl-c to stop");
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn cmd_client(args: &Args) -> Result<()> {
    let addr = args.flags.get("addr").context("need --addr HOST:PORT")?;
    let mut client = Client::connect(addr.as_str())?;
    if args.switches.contains("stats") {
        println!("{}", client.stats()?);
        return Ok(());
    }
    bail!("client currently supports --stats; use the library or examples for data transfer")
}
