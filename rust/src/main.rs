//! `bbans` — command-line front end for the BB-ANS compression system.
//!
//! Subcommands:
//!   info       [-i FILE]         artifact/model info, or container inspection
//!   compress   -m MODEL -i IDX -o FILE [-n N] [-v] [--native] [--latent-bits B]
//!              [--format bbc4] [--resume]
//!   decompress -i FILE -o IDX [--native] [--salvage]
//!   verify     -i FILE           integrity-check a container without decoding
//!   serve      [--bind ADDR] [--native] [--max-jobs J] [--max-batch-delay-ms D]
//!              [--queue-cap Q] [--fanout-workers W] [--request-ttl-ms T]
//!              [--quarantine-after K] [--drain-timeout-ms D]
//!              [--metrics-addr ADDR] [--no-trace] [--serve-dir DIR]
//!   client     --addr ADDR --stats|--health|--metrics|--trace|--drain [--pretty]
//!   fetch      --addr ADDR --name NAME -o FILE [--max-pages N]
//!
//! Arg parsing is hand-rolled (clap is unavailable offline).

use std::collections::VecDeque;
use std::path::PathBuf;

use anyhow::{anyhow, bail, Context, Result};

use bbans::bbans::bbc4::{Bbc4Container, Bbc4Model, Bbc4StreamWriter, Resumed, MAGIC_BBC4};
use bbans::bbans::container::{
    Container, HierContainer, ParallelContainer, MAGIC, MAGIC_HIER, MAGIC_PARALLEL,
};
use bbans::bbans::hierarchy::{HierCodec, Schedule};
use bbans::bbans::{BbAnsConfig, VaeCodec};
use bbans::coordinator::{Client, ModelService, PageStore, Server, ServiceParams};
use bbans::data;
use bbans::format::stream::FileMedium;
use bbans::model::hierarchy::{HierMeta, HierVae};
use bbans::model::vae::load_native;
use bbans::model::{Backend, Likelihood};
use bbans::runtime::{default_artifact_dir, load_config};

/// Default weight seed of CLI-built hierarchical models (any nonzero value
/// works; encoder and decoder derive identical weights from the header).
const DEFAULT_HIER_SEED: u64 = 0xB175_3A77;

struct Args {
    positional: Vec<String>,
    flags: std::collections::HashMap<String, String>,
    switches: std::collections::HashSet<String>,
}

fn parse_args(argv: &[String]) -> Args {
    let mut a = Args {
        positional: Vec::new(),
        flags: Default::default(),
        switches: Default::default(),
    };
    let mut q: VecDeque<_> = argv.iter().cloned().collect();
    while let Some(arg) = q.pop_front() {
        if let Some(name) = arg.strip_prefix("--") {
            if let Some((k, v)) = name.split_once('=') {
                a.flags.insert(k.to_string(), v.to_string());
            } else if q.front().map(|n| !n.starts_with('-')).unwrap_or(false) && !is_switch(name) {
                a.flags.insert(name.to_string(), q.pop_front().unwrap());
            } else {
                a.switches.insert(name.to_string());
            }
        } else if let Some(short) = arg.strip_prefix('-') {
            let name = match short {
                "m" => "model",
                "i" => "input",
                "o" => "output",
                "n" => "count",
                // `-v` is a switch (verbose), not a valued flag: it must
                // not swallow the token after it.
                "v" => {
                    a.switches.insert("verbose".to_string());
                    continue;
                }
                other => other,
            };
            if let Some(v) = q.pop_front() {
                a.flags.insert(name.to_string(), v);
            }
        } else {
            a.positional.push(arg);
        }
    }
    a
}

fn is_switch(name: &str) -> bool {
    matches!(
        name,
        "native"
            | "stats"
            | "binarized"
            | "help"
            | "salvage"
            | "health"
            | "drain"
            | "pretty"
            | "trace"
            | "metrics"
            | "verbose"
            | "no-trace"
            | "resume"
    )
}

fn usage() -> ! {
    eprintln!(
        "usage: bbans <info|compress|decompress|verify|serve|client|fetch> [args]\n\
         \n\
         bbans info       [-i FILE]\n\
         bbans compress   -m bin|full -i images.idx -o out.bbc [-n N] [-v] [--native]\n\
                          [--chunks K] [--format bbc4] [--resume]\n\
         bbans compress   --layers L -i images.idx -o out.bbc [--schedule naive|bitswap]\n\
                          [--hier-dims 32,16,8] [--hier-hidden H] [--hier-seed S]\n\
                          [--binarized] [--chunks K] [--format bbc4] [--resume] [-v]\n\
         bbans decompress -i in.bbc -o out.idx [--native] [--salvage]\n\
         bbans verify     -i in.bbc\n\
         bbans serve      [--bind 127.0.0.1:7878] [--native] [--max-jobs 16]\n\
                          [--max-batch-delay-ms 2] [--queue-cap 256] [--fanout-workers W]\n\
                          [--request-ttl-ms T] [--quarantine-after 3]\n\
                          [--drain-timeout-ms 30000] [--metrics-addr 127.0.0.1:9102]\n\
                          [--no-trace] [--serve-dir DIR]\n\
         bbans client     --addr HOST:PORT --stats|--health|--metrics|--drain [--pretty]\n\
         bbans client     --addr HOST:PORT --trace [--trace-max N] [--pretty]\n\
         bbans fetch      --addr HOST:PORT --name out.bbc4 -o local.bbc4 [--max-pages N]\n\
         \n\
         -v prints the bits-back rate ledger: measured bits/dim decomposed\n\
         into data, per-layer latent, and chain-startup (initial bits)\n\
         terms. The ledger observes the encode without changing any bytes.\n\
         serve enables request tracing by default (--no-trace disables it);\n\
         --metrics-addr exposes Prometheus text-format metrics over HTTP.\n\
         client --trace fetches recent server-side span trees as JSON;\n\
         --pretty renders JSON replies as an aligned key/value table.\n\
         \n\
         --chunks K > 1 encodes K independent chains on K threads (native\n\
         backend; produces a BBC2 chunk-parallel container).\n\
         --layers L codes through an L-layer hierarchical VAE (Bit-Swap by\n\
         default; produces a self-describing BBC3 container that any bbans\n\
         binary can decode without artifacts).\n\
         --format bbc4 wraps each chain in a CRC-framed page with a redundant\n\
         trailer index; `verify` checks integrity without decoding and\n\
         `decompress --salvage` recovers every intact page after damage.\n\
         --format bbc4 --resume streams pages to disk with a crash journal\n\
         (out + out.journal): rerun the identical command after a power cut\n\
         and it continues at the exact next page.\n\
         serve --serve-dir DIR additionally serves BBC4 files in DIR to\n\
         `bbans fetch`, which pulls page ranges with per-page CRC echo and\n\
         restarts a dropped transfer at the last intact local page.\n\
         \n\
         Artifacts default to ./artifacts ($BBANS_ARTIFACTS overrides)."
    );
    std::process::exit(2)
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        usage();
    }
    let cmd = argv[0].clone();
    let args = parse_args(&argv[1..]);
    let result = match cmd.as_str() {
        "info" => cmd_info(&args),
        "compress" => cmd_compress(&args),
        "decompress" => cmd_decompress(&args),
        "verify" => cmd_verify(&args),
        "serve" => cmd_serve(&args),
        "client" => cmd_client(&args),
        "fetch" => cmd_fetch(&args),
        _ => usage(),
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn service(args: &Args) -> ModelService {
    let params = ServiceParams {
        max_jobs: args
            .flags
            .get("max-jobs")
            .and_then(|v| v.parse().ok())
            .unwrap_or(16),
        // `--window-ms` is the pre-admission-rework spelling; keep it as
        // a fallback alias so existing invocations stay valid.
        max_batch_delay: std::time::Duration::from_millis(
            args.flags
                .get("max-batch-delay-ms")
                .or_else(|| args.flags.get("window-ms"))
                .and_then(|v| v.parse().ok())
                .unwrap_or(2),
        ),
        queue_cap: args
            .flags
            .get("queue-cap")
            .and_then(|v| v.parse().ok())
            .unwrap_or(256),
        bbans: bbans_config(args),
        fanout_workers: args
            .flags
            .get("fanout-workers")
            .and_then(|v| v.parse().ok())
            .unwrap_or(0),
        // Default: no deadline — a queued job waits as long as its client
        // does. Set `--request-ttl-ms` to shed abandoned jobs unprompted.
        default_ttl: args
            .flags
            .get("request-ttl-ms")
            .and_then(|v| v.parse().ok())
            .map(std::time::Duration::from_millis),
        quarantine_after: args
            .flags
            .get("quarantine-after")
            .and_then(|v| v.parse().ok())
            .unwrap_or(3),
    };
    ModelService::spawn(
        default_artifact_dir(),
        !args.switches.contains("native"),
        params,
    )
}

fn bbans_config(args: &Args) -> BbAnsConfig {
    let mut cfg = BbAnsConfig::default();
    if let Some(b) = args.flags.get("latent-bits").and_then(|v| v.parse().ok()) {
        cfg.latent_bits = b;
    }
    if let Some(p) = args.flags.get("pixel-prec").and_then(|v| v.parse().ok()) {
        cfg.pixel_prec = p;
    }
    cfg
}

/// Atomic output write: stage the bytes in a temp file **in the target
/// directory** (same filesystem, so the rename cannot cross devices) and
/// rename over the destination only on success. A crashed or failed run
/// never leaves a truncated half-container at the output path.
fn write_atomic(path: &std::path::Path, bytes: &[u8]) -> Result<()> {
    let dir = match path.parent() {
        Some(d) if !d.as_os_str().is_empty() => d.to_path_buf(),
        _ => PathBuf::from("."),
    };
    let base = path
        .file_name()
        .and_then(|n| n.to_str())
        .unwrap_or("out");
    let tmp = dir.join(format!(".{base}.tmp.{}", std::process::id()));
    std::fs::write(&tmp, bytes).with_context(|| format!("write {}", tmp.display()))?;
    if let Err(e) = std::fs::rename(&tmp, path) {
        let _ = std::fs::remove_file(&tmp);
        return Err(e)
            .with_context(|| format!("rename {} -> {}", tmp.display(), path.display()));
    }
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    if let Some(input) = args.flags.get("input") {
        return container_info(&PathBuf::from(input));
    }
    let dir = default_artifact_dir();
    let config = load_config(&dir)?;
    println!("artifact dir : {}", dir.display());
    println!("simd kernel  : {}", bbans::simd::kernel_name());
    println!(
        "pixels       : {}",
        config
            .req("pixels")
            .map_err(|e| anyhow!("{e}"))?
            .as_u64()
            .unwrap()
    );
    if let Some(bbans::util::json::Json::Obj(models)) = config.get("models") {
        for (name, m) in models {
            println!(
                "model '{name}': latent={} hidden={} likelihood={} test-ELBO={:.4} bits/dim",
                m.get("latent_dim").and_then(|v| v.as_u64()).unwrap_or(0),
                m.get("hidden").and_then(|v| v.as_u64()).unwrap_or(0),
                m.get("likelihood").and_then(|v| v.as_str()).unwrap_or("?"),
                m.get("test_elbo_bpd")
                    .and_then(|v| v.as_f64())
                    .unwrap_or(f64::NAN),
            );
        }
    }
    Ok(())
}

/// `info -i FILE`: report a container's format and what integrity signal
/// it carries (none, or per-page CRC with a salvageable index).
fn container_info(input: &std::path::Path) -> Result<()> {
    let bytes =
        std::fs::read(input).with_context(|| format!("read {}", input.display()))?;
    let magic: &[u8] = if bytes.len() >= 4 { &bytes[0..4] } else { &[] };
    println!("file      : {}", input.display());
    println!("size      : {} bytes", bytes.len());
    if magic == MAGIC_BBC4 {
        let s = Bbc4Container::salvage(&bytes)?;
        let c = &s.container;
        let kind = match &c.model {
            Bbc4Model::Vae { .. } => "single-layer VAE".to_string(),
            Bbc4Model::Hier { dims, .. } => format!("{}-layer hierarchy", dims.len()),
        };
        println!("format    : BBC4 v1 ({kind})");
        println!(
            "model     : {} (backend {})",
            c.model.name(),
            c.model.backend_id()
        );
        println!("images    : {} across {} pages", c.num_images, c.n_pages);
        println!(
            "integrity : per-page CRC-32 + CRC'd header + redundant trailer \
             index (salvageable with `decompress --salvage`)"
        );
        if s.report.is_clean() {
            println!("status    : intact ({})", s.report.summary());
        } else {
            println!("status    : DAMAGED ({})", s.report.summary());
        }
        return Ok(());
    }
    let (name, detail) = if magic == MAGIC_HIER {
        let hc = HierContainer::from_bytes(&bytes)?;
        (
            "BBC3",
            format!(
                "{}-layer hierarchy, {} chunks, {} images",
                hc.dims.len(),
                hc.chunks.len(),
                hc.num_images()
            ),
        )
    } else if magic == MAGIC_PARALLEL {
        let pc = ParallelContainer::from_bytes(&bytes)?;
        (
            "BBC2",
            format!(
                "model '{}', {} chunks, {} images",
                pc.model,
                pc.chunks.len(),
                pc.num_images()
            ),
        )
    } else if magic == MAGIC {
        let c = Container::from_bytes(&bytes)?;
        (
            "BBC1",
            format!("model '{}', single chain, {} images", c.model, c.num_images),
        )
    } else {
        bail!("unrecognized container magic (not BBC1/BBC2/BBC3/BBC4)");
    };
    println!("format    : {name}");
    println!("layout    : {detail}");
    println!(
        "integrity : none — {name} carries no checksums; corruption surfaces \
         as a parse error or garbage pixels (re-encode with --format bbc4)"
    );
    Ok(())
}

/// `verify -i FILE`: integrity-check a container without decoding pixels.
/// Exits nonzero when any page fails its checksum. Pre-BBC4 formats can
/// only be structurally parsed — they carry no integrity data.
fn cmd_verify(args: &Args) -> Result<()> {
    let input = PathBuf::from(args.flags.get("input").context("need -i FILE")?);
    let bytes =
        std::fs::read(&input).with_context(|| format!("read {}", input.display()))?;
    let magic: &[u8] = if bytes.len() >= 4 { &bytes[0..4] } else { &[] };
    if magic == MAGIC_BBC4 {
        let s = Bbc4Container::salvage(&bytes)?;
        let r = &s.report;
        println!("{}: BBC4, {}", input.display(), r.summary());
        if r.is_clean() {
            println!("all pages pass CRC; header and trailer index intact");
            return Ok(());
        }
        for (start, end) in &r.damaged_ranges {
            println!("  damaged byte range [{start}, {end})");
        }
        if !r.images_lost.is_empty() {
            println!("  unrecoverable image indices: {:?}", r.images_lost);
        }
        bail!(
            "{} of {} pages failed verification",
            r.pages_total - r.pages_recovered,
            r.pages_total
        );
    }
    let (name, detail) = if magic == MAGIC_HIER {
        let hc = HierContainer::from_bytes(&bytes)?;
        (
            "BBC3",
            format!("{} chunks, {} images", hc.chunks.len(), hc.num_images()),
        )
    } else if magic == MAGIC_PARALLEL {
        let pc = ParallelContainer::from_bytes(&bytes)?;
        (
            "BBC2",
            format!("{} chunks, {} images", pc.chunks.len(), pc.num_images()),
        )
    } else if magic == MAGIC {
        let c = Container::from_bytes(&bytes)?;
        ("BBC1", format!("single chain, {} images", c.num_images))
    } else {
        bail!("unrecognized container magic (not BBC1/BBC2/BBC3/BBC4)");
    };
    println!(
        "{}: {name}, {detail}; structure parses, but {name} carries no \
         checksums — damage cannot be detected (re-encode with --format bbc4)",
        input.display()
    );
    Ok(())
}

fn cmd_compress(args: &Args) -> Result<()> {
    let input = PathBuf::from(args.flags.get("input").context("need -i IDX")?);
    let output = PathBuf::from(args.flags.get("output").context("need -o FILE")?);
    let ds = data::load_idx_images(&input)
        .with_context(|| format!("read {}", input.display()))?;
    let n = args
        .flags
        .get("count")
        .and_then(|v| v.parse().ok())
        .unwrap_or(ds.len());
    let (rows, cols) = (ds.rows, ds.cols);
    let images: Vec<Vec<u8>> = ds.images.into_iter().take(n).collect();
    let raw_bytes = images.len() * rows * cols;

    let chunks: usize = match args.flags.get("chunks") {
        Some(v) => v
            .parse()
            .map_err(|_| anyhow!("invalid --chunks value '{v}' (want a positive integer)"))?,
        None => 1,
    };
    let bbc4 = match args.flags.get("format").map(String::as_str) {
        None => false,
        Some("bbc4") => true,
        Some(other) => bail!(
            "unsupported --format '{other}' (supported: bbc4; omit the flag \
             for the default container each path produces)"
        ),
    };

    let verbose = args.switches.contains("verbose");
    if verbose && bbc4 {
        bail!(
            "-v rate-ledger reporting is not wired for --format bbc4 yet; \
             drop one of the two flags"
        );
    }
    if args.switches.contains("resume") && !bbc4 {
        bail!("--resume requires --format bbc4 (the only journaled, streamable container)");
    }

    if args.flags.contains_key("layers") {
        return cmd_compress_hier(args, images, rows * cols, raw_bytes, chunks, bbc4, &output);
    }

    let model = args.flags.get("model").context("need -m MODEL")?.clone();
    if bbc4 {
        // Integrity-checked paged container: one CRC-framed page per chain
        // plus a redundant trailer index, so `decompress --salvage` can
        // recover intact pages after partial damage. Encodes on the native
        // backend like the BBC2 path (pages are coded on threads).
        let backend = load_native(default_artifact_dir(), &model)?;
        let codec = VaeCodec::new(&backend, bbans_config(args))?;
        if args.switches.contains("resume") {
            let shell = Bbc4Container::new_shell(
                Bbc4Model::for_vae(&codec),
                codec.cfg,
                backend.meta().pixels as u32,
                images.len() as u32,
                chunks as u32,
            )?;
            return stream_compress_bbc4(&output, shell, |w| w.encode_next_vae(&codec, &images));
        }
        let t = std::time::Instant::now();
        let container = Bbc4Container::encode_vae(&codec, &images, chunks)?;
        let dt = t.elapsed();
        let bytes = container.to_bytes();
        write_atomic(&output, &bytes)?;
        let n_images = container.num_images;
        let bpd = bytes.len() as f64 * 8.0 / (n_images as f64 * container.pixels as f64);
        println!(
            "compressed {n_images} images into {} integrity-checked pages (BBC4): \
             {raw_bytes} -> {} bytes ({bpd:.4} bits/dim) in {:.2}s ({:.1} img/s)",
            container.n_pages,
            bytes.len(),
            dt.as_secs_f64(),
            n_images as f64 / dt.as_secs_f64(),
        );
        return Ok(());
    }
    if chunks > 1 {
        // Chunk-parallel fast path: independent chains on threads, native
        // backend (the PJRT handles are not Sync; it parallelizes through
        // the serving batcher instead).
        let backend = load_native(default_artifact_dir(), &model)?;
        let codec = VaeCodec::new(&backend, bbans_config(args))?;
        let t = std::time::Instant::now();
        let (container, ledger) = if verbose {
            let (c, l) = ParallelContainer::encode_with_ledger(&codec, &images, chunks)?;
            (c, Some(l))
        } else {
            (ParallelContainer::encode_with(&codec, &images, chunks)?, None)
        };
        let dt = t.elapsed();
        let bytes = container.to_bytes();
        write_atomic(&output, &bytes)?;
        let n_images = container.num_images();
        let bpd = bytes.len() as f64 * 8.0 / (n_images as f64 * container.pixels as f64);
        println!(
            "compressed {n_images} images in {} chunks: {raw_bytes} -> {} bytes \
             ({bpd:.4} bits/dim) in {:.2}s ({:.1} img/s)",
            container.chunks.len(),
            bytes.len(),
            dt.as_secs_f64(),
            n_images as f64 / dt.as_secs_f64(),
        );
        if let Some(l) = ledger {
            print_ledger(&l, container.pixels as usize, backend.meta().test_elbo_bpd);
        }
        return Ok(());
    }

    if verbose {
        // Ledgered single-chain encode: runs offline on the native backend
        // (the rate ledger hooks into the local codec, not the serving
        // path) and writes the same BBC1 layout the service produces.
        let backend = load_native(default_artifact_dir(), &model)?;
        let codec = VaeCodec::new(&backend, bbans_config(args))?;
        let t = std::time::Instant::now();
        let (ans, _stats, ledger) = codec.encode_dataset_ledgered(&images)?;
        let dt = t.elapsed();
        let meta = backend.meta();
        let container = Container {
            model: meta.name.clone(),
            backend_id: backend.backend_id(),
            cfg: codec.cfg,
            num_images: images.len() as u32,
            pixels: meta.pixels as u32,
            message: ans.into_message(),
        };
        let bytes = container.to_bytes();
        write_atomic(&output, &bytes)?;
        println!(
            "compressed {} images: {raw_bytes} -> {} bytes ({:.4} bits/dim) in {:.2}s \
             ({:.1} img/s)",
            container.num_images,
            bytes.len(),
            container.bits_per_dim(),
            dt.as_secs_f64(),
            container.num_images as f64 / dt.as_secs_f64(),
        );
        print_ledger(&ledger, meta.pixels, meta.test_elbo_bpd);
        return Ok(());
    }

    let svc = service(args);
    let h = svc.handle();
    let t = std::time::Instant::now();
    let container = h.compress(&model, images)?;
    let dt = t.elapsed();
    write_atomic(&output, &container)?;
    let parsed = Container::from_bytes(&container)?;
    println!(
        "compressed {} images: {} -> {} bytes ({:.4} bits/dim) in {:.2}s ({:.1} img/s)",
        parsed.num_images,
        raw_bytes,
        container.len(),
        parsed.bits_per_dim(),
        dt.as_secs_f64(),
        parsed.num_images as f64 / dt.as_secs_f64(),
    );
    svc.shutdown();
    Ok(())
}

/// `compress --layers L`: code through an L-layer hierarchical VAE into a
/// self-describing `BBC3` container. No artifacts are needed — the model
/// is derived deterministically from `--hier-seed` and its geometry, both
/// recorded in the header, so any `bbans` binary can decode the result.
fn cmd_compress_hier(
    args: &Args,
    mut images: Vec<Vec<u8>>,
    pixels: usize,
    raw_bytes: usize,
    chunks: usize,
    bbc4: bool,
    output: &std::path::Path,
) -> Result<()> {
    let layers: usize = args
        .flags
        .get("layers")
        .expect("checked by caller")
        .parse()
        .map_err(|_| anyhow!("invalid --layers value"))?;
    if !(1..=8).contains(&layers) {
        bail!("--layers must be in 1..=8");
    }
    let schedule = match args.flags.get("schedule") {
        Some(s) => Schedule::parse(s)?,
        None => Schedule::BitSwap,
    };
    let dims: Vec<usize> = match args.flags.get("hier-dims") {
        Some(v) => {
            let parsed: Result<Vec<usize>> = v
                .split(',')
                .map(|s| {
                    s.trim()
                        .parse::<usize>()
                        .map_err(|_| anyhow!("invalid --hier-dims value '{v}'"))
                })
                .collect();
            parsed?
        }
        // Default: geometric halving from 32, e.g. L=3 → 32,16,8.
        None => (0..layers).map(|l| (32usize >> l).max(2)).collect(),
    };
    if dims.len() != layers {
        bail!("--hier-dims lists {} layers, --layers says {layers}", dims.len());
    }
    if dims.iter().any(|&d| d == 0 || d > 1 << 16) {
        bail!("--hier-dims entries must be in 1..=65536 (got {dims:?})");
    }
    let hidden: usize = args
        .flags
        .get("hier-hidden")
        .map(|v| v.parse())
        .transpose()
        .map_err(|_| anyhow!("invalid --hier-hidden value"))?
        .unwrap_or(64);
    if hidden == 0 || hidden > 1 << 20 {
        bail!("--hier-hidden must be in 1..=1048576");
    }
    let seed: u64 = args
        .flags
        .get("hier-seed")
        .map(|v| v.parse())
        .transpose()
        .map_err(|_| anyhow!("invalid --hier-seed value"))?
        .unwrap_or(DEFAULT_HIER_SEED);
    if seed == 0 {
        bail!("--hier-seed must be nonzero (0 is reserved for artifact-backed models)");
    }
    let likelihood = if args.switches.contains("binarized") {
        // A Bernoulli likelihood codes pixels as zero/nonzero, so make the
        // data genuinely binary up front to keep the roundtrip lossless.
        for img in &mut images {
            for v in img.iter_mut() {
                *v = (*v != 0) as u8;
            }
        }
        Likelihood::Bernoulli
    } else {
        Likelihood::BetaBinomial
    };

    let meta = HierMeta {
        name: format!("hier{layers}"),
        pixels,
        dims,
        hidden,
        likelihood,
    };
    let backend = HierVae::random(meta, seed);
    let codec = HierCodec::new(&backend, bbans_config(args), schedule)?;
    if bbc4 {
        if args.switches.contains("resume") {
            let shell = Bbc4Container::new_shell(
                Bbc4Model::for_hier(&codec),
                codec.cfg,
                pixels as u32,
                images.len() as u32,
                chunks as u32,
            )?;
            return stream_compress_bbc4(output, shell, |w| w.encode_next_hier(&codec, &images));
        }
        let t = std::time::Instant::now();
        let container = Bbc4Container::encode_hier(&codec, &images, chunks)?;
        let dt = t.elapsed();
        let bytes = container.to_bytes();
        write_atomic(output, &bytes)?;
        let n_images = container.num_images;
        let bpd = bytes.len() as f64 * 8.0 / (n_images as f64 * container.pixels as f64);
        println!(
            "compressed {n_images} images through {layers}-layer hierarchy ({} schedule) \
             into {} integrity-checked pages (BBC4): {raw_bytes} -> {} bytes \
             ({bpd:.4} bits/dim) in {:.2}s ({:.1} img/s)",
            schedule.name(),
            container.n_pages,
            bytes.len(),
            dt.as_secs_f64(),
            n_images as f64 / dt.as_secs_f64(),
        );
        return Ok(());
    }
    let t = std::time::Instant::now();
    let (container, ledger) = if args.switches.contains("verbose") {
        let (c, l) = HierContainer::encode_with_ledger(&codec, &images, chunks)?;
        (c, Some(l))
    } else {
        (HierContainer::encode_with(&codec, &images, chunks)?, None)
    };
    let dt = t.elapsed();
    let bytes = container.to_bytes();
    write_atomic(output, &bytes)?;
    let n_images = container.num_images();
    let bpd = bytes.len() as f64 * 8.0 / (n_images as f64 * container.pixels as f64);
    println!(
        "compressed {n_images} images through {layers}-layer hierarchy ({} schedule, \
         {} chunks): {raw_bytes} -> {} bytes ({bpd:.4} bits/dim) in {:.2}s ({:.1} img/s)",
        schedule.name(),
        container.chunks.len(),
        bytes.len(),
        dt.as_secs_f64(),
        n_images as f64 / dt.as_secs_f64(),
    );
    if let Some(l) = ledger {
        // Hierarchical CLI models are seed-derived, not trained: there is
        // no recorded test ELBO to compare the measured rate against.
        print_ledger(&l, pixels, f64::NAN);
    }
    Ok(())
}

/// `compress --format bbc4 --resume`: crash-consistent streaming encode.
/// The writer appends one durable CRC-framed page at a time to `output`
/// and journals progress in `output.journal`; rerunning the identical
/// command after an interruption validates the journal against the file,
/// truncates any torn tail, and continues at the exact next page. The
/// uninterrupted result is byte-identical to the one-shot `--format bbc4`
/// encode.
fn stream_compress_bbc4(
    output: &std::path::Path,
    shell: Bbc4Container,
    mut encode_next: impl FnMut(&mut Bbc4StreamWriter<FileMedium, FileMedium>) -> Result<bool>,
) -> Result<()> {
    let n_pages = shell.n_pages;
    let n_images = shell.num_images;
    let t = std::time::Instant::now();
    let mut w = match Bbc4StreamWriter::resume(output, shell)? {
        Resumed::Complete => {
            println!(
                "{} is already a complete BBC4 container; nothing to resume",
                output.display()
            );
            return Ok(());
        }
        Resumed::Writer(w) => *w,
    };
    let skipped = w.pages_done();
    if skipped > 0 {
        println!(
            "resuming at page {skipped} of {n_pages} ({} images already durable, {} bytes kept)",
            w.images_done(),
            w.bytes_written()
        );
    }
    let mut encoded = 0u32;
    while encode_next(&mut w)? {
        encoded += 1;
    }
    w.finish_file()?;
    let dt = t.elapsed();
    let bytes = std::fs::metadata(output)
        .with_context(|| format!("stat {}", output.display()))?
        .len();
    println!(
        "streamed {n_images} images into {n_pages} journaled pages (BBC4): {bytes} bytes \
         ({encoded} page(s) encoded this run, {skipped} resumed) in {:.2}s -> {}",
        dt.as_secs_f64(),
        output.display()
    );
    Ok(())
}

/// `fetch --addr A --name NAME -o FILE`: pull a BBC4 container from a
/// serving peer page-range-by-page-range. The local file is persisted
/// after every range, so a dropped transfer rerun with the same command
/// restarts at the first page missing locally — already-intact pages are
/// never re-sent.
fn cmd_fetch(args: &Args) -> Result<()> {
    let addr = args.flags.get("addr").context("need --addr HOST:PORT")?;
    let name = args
        .flags
        .get("name")
        .context("need --name NAME (container file name in the server's --serve-dir)")?;
    let output = PathBuf::from(args.flags.get("output").context("need -o FILE")?);
    let batch: u32 = args
        .flags
        .get("max-pages")
        .map(|v| v.parse())
        .transpose()
        .map_err(|_| anyhow!("invalid --max-pages value"))?
        .unwrap_or(4);
    if batch == 0 {
        bail!("--max-pages must be nonzero");
    }

    // Resume: keep the longest valid page prefix already on disk and
    // restart the transfer at the first missing page.
    let mut have = match std::fs::read(&output) {
        Ok(b) => b,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => Vec::new(),
        Err(e) => return Err(e).with_context(|| format!("read {}", output.display())),
    };
    let mut from = 0u32;
    if !have.is_empty() {
        let (shell, prefix) = Bbc4Container::scan_prefix(&have).with_context(|| {
            format!(
                "{} exists but is not a resumable BBC4 prefix (use a fresh -o path)",
                output.display()
            )
        })?;
        if prefix.complete {
            println!("{} is already complete; nothing to fetch", output.display());
            return Ok(());
        }
        have.truncate(prefix.keep);
        from = prefix.pages;
        if from > 0 {
            println!(
                "resuming fetch at page {from} of {} ({} intact bytes kept)",
                shell.n_pages,
                have.len()
            );
        }
    }

    let t = std::time::Instant::now();
    let mut client = Client::connect(addr.as_str())?;
    let mut fetched = 0u32;
    loop {
        // All pages present but the trailer missing: refetch only the
        // final range and keep just its trailer bytes.
        let trailer_only = from > 0 && {
            let (shell, _) = Bbc4Container::scan_prefix(&have)?;
            from == shell.n_pages
        };
        let req_from = if trailer_only { from - 1 } else { from };
        let range = client.fetch_pages(name, req_from, batch)?;
        if range.pages.is_empty() {
            bail!("server returned an empty page range at page {req_from}");
        }
        if from == 0 {
            have.extend_from_slice(&range.header);
        }
        if !trailer_only {
            for pg in &range.pages {
                have.extend_from_slice(&pg.bytes);
                fetched += 1;
            }
            from += range.pages.len() as u32;
        }
        if from >= range.n_pages {
            have.extend_from_slice(&range.trailer);
            write_atomic(&output, &have)?;
            let (shell, prefix) = Bbc4Container::scan_prefix(&have)?;
            if !prefix.complete {
                bail!(
                    "assembled file failed strict validation ({} of {} pages intact); \
                     rerun fetch to retry",
                    prefix.pages,
                    shell.n_pages
                );
            }
            println!(
                "fetched {fetched} page(s) of '{name}' ({} pages, {} images total): \
                 {} bytes in {:.2}s -> {}",
                shell.n_pages,
                shell.num_images,
                have.len(),
                t.elapsed().as_secs_f64(),
                output.display()
            );
            return Ok(());
        }
        // Persist progress after every range so an interrupted transfer
        // resumes here instead of from page 0.
        write_atomic(&output, &have)?;
    }
}

fn cmd_decompress(args: &Args) -> Result<()> {
    let input = PathBuf::from(args.flags.get("input").context("need -i FILE")?);
    let output = PathBuf::from(args.flags.get("output").context("need -o IDX")?);
    let container =
        std::fs::read(&input).with_context(|| format!("read {}", input.display()))?;

    let is_bbc4 = container.len() >= 4 && &container[0..4] == MAGIC_BBC4;
    if args.switches.contains("salvage") && !is_bbc4 {
        bail!(
            "--salvage requires a BBC4 container (earlier formats carry no \
             per-page integrity data to salvage from)"
        );
    }
    if is_bbc4 {
        return decompress_bbc4(args, &container, &output);
    }

    if container.len() >= 4 && &container[0..4] == MAGIC_HIER {
        // Hierarchical container: the header is self-describing, so the
        // exact backend is rebuilt from it (no artifacts needed).
        let hc = HierContainer::from_bytes(&container)?;
        let backend = hc.build_backend()?;
        let codec = HierCodec::new(&backend, hc.cfg, hc.schedule)?;
        let t = std::time::Instant::now();
        let images = hc.decode_with(&codec)?;
        let dt = t.elapsed();
        let n = write_square_idx(images, &output)?;
        println!(
            "decompressed {n} images ({}-layer hierarchy, {} schedule, {} chunks) \
             in {:.2}s ({:.1} img/s) -> {}",
            hc.dims.len(),
            hc.schedule.name(),
            hc.chunks.len(),
            dt.as_secs_f64(),
            n as f64 / dt.as_secs_f64(),
            output.display()
        );
        return Ok(());
    }

    if container.len() >= 4 && &container[0..4] == MAGIC_PARALLEL {
        // Chunk-parallel container: decode chunks on threads with the
        // native backend named in the header.
        let pc = ParallelContainer::from_bytes(&container)?;
        let backend = load_native(default_artifact_dir(), &pc.model)?;
        if pc.backend_id != backend.backend_id() {
            bail!(
                "container encoded with backend '{}', local backend is '{}'",
                pc.backend_id,
                backend.backend_id()
            );
        }
        let codec = VaeCodec::new(&backend, pc.cfg)?;
        let t = std::time::Instant::now();
        let images = pc.decode_with(&codec)?;
        let dt = t.elapsed();
        let n = write_square_idx(images, &output)?;
        println!(
            "decompressed {n} images ({} chunks) in {:.2}s ({:.1} img/s) -> {}",
            pc.chunks.len(),
            dt.as_secs_f64(),
            n as f64 / dt.as_secs_f64(),
            output.display()
        );
        return Ok(());
    }

    let svc = service(args);
    let h = svc.handle();
    let t = std::time::Instant::now();
    let images = h.decompress(container)?;
    let dt = t.elapsed();
    let n = write_square_idx(images, &output)?;
    println!(
        "decompressed {n} images in {:.2}s ({:.1} img/s) -> {}",
        dt.as_secs_f64(),
        n as f64 / dt.as_secs_f64(),
        output.display()
    );
    svc.shutdown();
    Ok(())
}

/// Decode a BBC4 container: strict by default (any damage is an error);
/// `--salvage` decodes every intact page and reports what was lost.
fn decompress_bbc4(args: &Args, bytes: &[u8], output: &std::path::Path) -> Result<()> {
    let (c, report) = if args.switches.contains("salvage") {
        let s = Bbc4Container::salvage(bytes)?;
        (s.container, Some(s.report))
    } else {
        (Bbc4Container::from_bytes(bytes)?, None)
    };
    let t = std::time::Instant::now();
    let slots = match &c.model {
        Bbc4Model::Vae { model, backend_id } => {
            let backend = load_native(default_artifact_dir(), model)?;
            if *backend_id != backend.backend_id() {
                bail!(
                    "container encoded with backend '{backend_id}', local backend is '{}'",
                    backend.backend_id()
                );
            }
            let codec = VaeCodec::new(&backend, c.cfg)?;
            c.decode_slots_vae(&codec)?
        }
        Bbc4Model::Hier { schedule, .. } => {
            let backend = c.build_hier_backend()?;
            let codec = HierCodec::new(&backend, c.cfg, *schedule)?;
            c.decode_slots_hier(&codec)?
        }
    };
    let dt = t.elapsed();
    let images: Vec<Vec<u8>> = slots.into_iter().flatten().collect();
    let n = write_square_idx(images, output)?;
    match report {
        Some(r) if !r.is_clean() => {
            println!("salvage: {}", r.summary());
            for (start, end) in &r.damaged_ranges {
                println!("  damaged byte range [{start}, {end})");
            }
            if !r.images_lost.is_empty() {
                println!("  lost image indices: {:?}", r.images_lost);
            }
            println!(
                "recovered {n} of {} images in {:.2}s -> {}",
                r.images_total,
                dt.as_secs_f64(),
                output.display()
            );
        }
        _ => println!(
            "decompressed {n} images ({} CRC-verified pages) in {:.2}s ({:.1} img/s) -> {}",
            c.n_pages,
            dt.as_secs_f64(),
            n as f64 / dt.as_secs_f64(),
            output.display()
        ),
    }
    Ok(())
}

/// Write decoded images as a square-image IDX file; returns the count.
fn write_square_idx(images: Vec<Vec<u8>>, output: &std::path::Path) -> Result<usize> {
    let n = images.len();
    let side = (images.first().map(|i| i.len()).unwrap_or(0) as f64).sqrt() as usize;
    let ds = data::Dataset {
        rows: side,
        cols: side,
        images,
    };
    write_atomic(output, &data::write_idx_images(&ds))?;
    Ok(n)
}

fn cmd_serve(args: &Args) -> Result<()> {
    let bind = args
        .flags
        .get("bind")
        .cloned()
        .unwrap_or_else(|| "127.0.0.1:7878".to_string());
    let svc = service(args);
    // Request tracing is on by default: the disabled path is a single
    // relaxed atomic load, and the enabled path buffers spans thread-local,
    // so the cost is negligible either way (`--no-trace` still turns it off).
    if !args.switches.contains("no-trace") {
        bbans::obs::tracer().set_enabled(true);
    }
    let store = args
        .flags
        .get("serve-dir")
        .map(|d| std::sync::Arc::new(PageStore::new(d.clone())));
    let server = Server::start_with_store(
        &bind,
        svc.handle(),
        args.flags.get("metrics-addr").map(String::as_str),
        store,
    )?;
    println!("bbans serving on {}", server.addr);
    if let Some(ma) = server.metrics_addr {
        println!("metrics exposition on http://{ma}/ (Prometheus text 0.0.4)");
    }
    if let Some(dir) = args.flags.get("serve-dir") {
        println!("serving BBC4 page ranges from {dir} (`bbans fetch --name FILE`)");
    }
    if args.switches.contains("native") {
        // The native service fans lock-step phases over a Sync-backend
        // worker pool; the kernel variant is diagnostic only (all
        // variants are bit-identical — see README "SIMD dispatch").
        println!(
            "native Sync-backend fan-out service (compute kernel: {})",
            bbans::simd::kernel_name()
        );
    }
    println!("press ctrl-c to stop, or `bbans client --addr {bind} --drain` to drain");
    // Serve until a peer requests a drain over the wire, then shut down
    // gracefully: close the accept loop, let in-flight requests finish up
    // to the drain deadline, and stop the model worker.
    while !server.drain_requested() {
        std::thread::sleep(std::time::Duration::from_millis(100));
    }
    let timeout = std::time::Duration::from_millis(
        args.flags
            .get("drain-timeout-ms")
            .and_then(|v| v.parse().ok())
            .unwrap_or(30_000),
    );
    println!("drain requested; waiting up to {}ms for in-flight requests", timeout.as_millis());
    let clean = server.drain(timeout);
    svc.shutdown();
    if clean {
        println!("drained cleanly");
        Ok(())
    } else {
        bail!("drain deadline exceeded; remaining connections were stopped")
    }
}

fn cmd_client(args: &Args) -> Result<()> {
    let addr = args.flags.get("addr").context("need --addr HOST:PORT")?;
    let mut client = Client::connect(addr.as_str())?;
    let pretty = args.switches.contains("pretty");
    // Every requested probe runs over this ONE connection, in a fixed
    // order. Combining probes (e.g. `--trace --metrics`) used to stop at
    // the first match; now a request and its snapshot probes share a
    // connection, so the probes observe the same server the request hit
    // instead of a fresh dial's view.
    let mut ran = false;
    if args.switches.contains("stats") {
        print_json_doc(&client.stats()?, pretty)?;
        ran = true;
    }
    if args.switches.contains("health") {
        print_json_doc(&client.health()?, pretty)?;
        ran = true;
    }
    if args.switches.contains("trace") {
        let max: u32 = args
            .flags
            .get("trace-max")
            .map(|v| v.parse())
            .transpose()
            .map_err(|_| anyhow!("invalid --trace-max value"))?
            .unwrap_or(8);
        print_json_doc(&client.trace(max)?, pretty)?;
        ran = true;
    }
    if args.switches.contains("metrics") {
        print!("{}", client.metrics_text()?);
        ran = true;
    }
    if args.switches.contains("drain") {
        client.shutdown_server()?;
        println!("drain requested");
        ran = true;
    }
    if !ran {
        bail!(
            "client supports --stats, --health, --metrics, --trace, and --drain; \
             use the library or examples for data transfer"
        );
    }
    Ok(())
}

/// Print a JSON reply either raw (stable, machine-readable) or, under
/// `--pretty`, as an aligned key/value table using dotted paths for
/// nesting and `[i]` suffixes for array elements.
fn print_json_doc(json: &str, pretty: bool) -> Result<()> {
    if !pretty {
        println!("{json}");
        return Ok(());
    }
    let v = bbans::util::json::Json::parse(json)
        .map_err(|e| anyhow!("reply is not valid JSON: {e:?}"))?;
    let mut rows: Vec<(String, String)> = Vec::new();
    flatten_json("", &v, &mut rows);
    let w = rows.iter().map(|(k, _)| k.len()).max().unwrap_or(0);
    for (k, val) in rows {
        println!("{k:<w$}  {val}");
    }
    Ok(())
}

fn flatten_json(prefix: &str, v: &bbans::util::json::Json, out: &mut Vec<(String, String)>) {
    use bbans::util::json::Json;
    match v {
        Json::Obj(fields) => {
            if fields.is_empty() {
                out.push((prefix.to_string(), "{}".to_string()));
            }
            for (k, child) in fields {
                let key = if prefix.is_empty() {
                    k.clone()
                } else {
                    format!("{prefix}.{k}")
                };
                flatten_json(&key, child, out);
            }
        }
        Json::Arr(items) => {
            if items.is_empty() {
                out.push((prefix.to_string(), "[]".to_string()));
            }
            for (i, child) in items.iter().enumerate() {
                flatten_json(&format!("{prefix}[{i}]"), child, out);
            }
        }
        Json::Null => out.push((prefix.to_string(), "null".to_string())),
        Json::Bool(b) => out.push((prefix.to_string(), b.to_string())),
        Json::Num(n) => out.push((prefix.to_string(), format!("{n}"))),
        Json::Str(s) => out.push((prefix.to_string(), s.clone())),
    }
}

/// Print a rate-ledger decomposition (`compress -v`): measured bits/dim
/// split into data, per-layer latent, and chain-startup terms, next to the
/// model's training-time test ELBO when it is known.
fn print_ledger(ledger: &bbans::obs::Ledger, pixels: usize, test_elbo_bpd: f64) {
    let s = ledger.summary(pixels);
    println!("rate ledger ({} images, {} latent layer(s)):", s.images, s.layers);
    println!("  net (-ELBO est.)    : {:.4} bits/dim", s.net_bpd());
    println!("  data  -log p(x|z)   : {:.4} bits/dim", s.data_bpd());
    for l in 0..s.layers {
        println!(
            "  latent[{l}] (KL est.) : {:.4} bits/dim (pop {:.0} bits, push {:.0} bits)",
            s.latent_net_bpd(l),
            s.latent_pop_bits[l],
            s.latent_push_bits[l]
        );
    }
    println!(
        "  initial bits        : {:.0} total ({:.4} bits/dim amortized)",
        s.initial_bits,
        s.initial_bpd()
    );
    if test_elbo_bpd.is_finite() {
        println!(
            "  training test-ELBO  : {test_elbo_bpd:.4} bits/dim (measured gap {:+.4})",
            s.net_bpd() - test_elbo_bpd
        );
    }
    println!("  json                : {}", s.to_json());
}
