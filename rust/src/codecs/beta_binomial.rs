//! Beta-binomial pixel codec (paper §3.2: the likelihood for full,
//! non-binarized MNIST is a two-parameter discrete distribution per pixel).
//!
//! Two constructors:
//! * [`BetaBinomial::from_params`] — analytic PMF from `(α, β)` via
//!   `lgamma` (used by the native Rust backend and tests);
//! * [`BetaBinomial::from_pmf_row`] — a precomputed PMF row, as produced by
//!   the L1 Pallas kernel `bbpmf` inside the decoder HLO (the runtime path:
//!   the network hands Rust a ready `[pixels, 256]` table).
//!
//! Encoder and decoder must build the codec from the **same source** — the
//! container header records which backend produced the stream.

use super::categorical::Categorical;
use super::SymbolCodec;
use crate::ans::Ans;
use crate::util::math::beta_binomial_logpmf;

#[derive(Debug, Clone)]
pub struct BetaBinomial {
    inner: Categorical,
    pub n: u32,
}

impl BetaBinomial {
    /// Analytic construction from the distribution parameters.
    ///
    /// Perf (EXPERIMENTS.md §Perf #1): the PMF is built with the ratio
    /// recurrence
    /// `P(k+1)/P(k) = (n−k)(k+α) / ((k+1)(n−k−1+β))`
    /// — one multiply/divide per symbol instead of four `lgamma` calls,
    /// ~40× faster, then normalized (the quantizer renormalizes anyway).
    /// One `lgamma`-based anchor at the mode keeps the scale in f64 range.
    pub fn from_params(n: u32, alpha: f64, beta: f64, prec: u32) -> Self {
        // Guard against non-finite network outputs: fall back to uniform.
        let (alpha, beta) = if alpha.is_finite() && beta.is_finite() && alpha > 0.0 && beta > 0.0 {
            (alpha, beta)
        } else {
            (1.0, 1.0)
        };
        let nn = n as f64;
        let mut pmf = vec![0.0f64; n as usize + 1];
        // Anchor at k=0 in log space, then recurse upward, renormalizing
        // if the running value overflows/underflows is unnecessary since
        // we anchor at the true log-pmf of k=0 and the pmf is bounded by 1.
        let p0 = beta_binomial_logpmf(0, n, alpha, beta).exp();
        let mut cur = p0;
        pmf[0] = cur;
        for k in 0..n as usize {
            let kf = k as f64;
            let ratio = ((nn - kf) * (kf + alpha)) / ((kf + 1.0) * (nn - kf - 1.0 + beta));
            cur *= ratio;
            pmf[k + 1] = cur;
        }
        // Degenerate parameter corners can underflow p0 to 0; fall back to
        // the exact (slow) path there.
        if !cur.is_finite() || pmf.iter().all(|&p| p == 0.0) {
            pmf = (0..=n)
                .map(|k| beta_binomial_logpmf(k, n, alpha, beta).exp())
                .collect();
        }
        Self {
            inner: Categorical::from_pmf(&pmf, prec),
            n,
        }
    }

    /// Construction from a PMF row computed inside the model graph (f32).
    pub fn from_pmf_row(row: &[f32], prec: u32) -> Self {
        Self::from_pmf_row_scratch(row, prec, &mut Vec::new())
    }

    /// [`BetaBinomial::from_pmf_row`] reusing a caller-owned f64 buffer
    /// for the widened PMF row — the per-pixel table path builds one codec
    /// per pixel, and this keeps that loop free of the `Vec<f64>`
    /// allocation (ISSUE 2). Bit-identical to the allocating constructor.
    ///
    /// ISSUE 5: the widen+sanitize pass and the CDF quantization's
    /// multiply+round both run through the SIMD-dispatched helpers
    /// ([`crate::simd`]), still bit-identical to the historical loops
    /// (pinned by `scratch_row_construction_matches_allocating` plus the
    /// quantizer's own equivalence test).
    pub fn from_pmf_row_scratch(row: &[f32], prec: u32, pmf: &mut Vec<f64>) -> Self {
        let n = (row.len() - 1) as u32;
        crate::simd::widen_sanitize_f32(row, pmf);
        // A fully-zero row (pathological network output) degrades to
        // uniform rather than panicking. Entries are ≥ 0 and finite after
        // sanitization, so "sum ≤ 0" is exactly "no positive entry".
        if !pmf.iter().any(|&p| p > 0.0) {
            pmf.clear();
            pmf.resize(row.len(), 1.0);
        }
        Self {
            inner: Categorical::from_pmf_in_place(pmf, prec),
            n,
        }
    }

    pub fn bits(&self, sym: usize) -> f64 {
        self.inner.bits(sym)
    }

    /// The quantized CDF backing this codec (interval extraction for
    /// coder-generic paths).
    pub fn quantized(&self) -> &super::quantize::QuantizedCdf {
        self.inner.quantized()
    }
}

impl SymbolCodec for BetaBinomial {
    type Sym = u32;

    #[inline]
    fn push(&self, ans: &mut Ans, sym: u32) {
        debug_assert!(sym <= self.n);
        self.inner.push(ans, sym as usize);
    }

    #[inline]
    fn pop(&self, ans: &mut Ans) -> u32 {
        self.inner.pop(ans) as u32
    }
}

/// Lazy beta-binomial codec (EXPERIMENTS.md §Perf #3): computes only the
/// cumulative masses it needs via the PMF ratio recurrence — `O(sym)` work
/// per push/pop instead of building and quantizing the whole 256-entry
/// table. On MNIST most pixels are 0, so the common case is O(1).
///
/// Quantization uses the same strictly-monotone map as
/// [`super::quantize::QuantizedCdf`] (`G(j) = round(cum_j·scale) + j`) and
/// agrees with `from_params` in practice, but the floating-point paths
/// differ (unnormalized vs normalized anchor), so a stream must use ONE
/// construction for both encode and decode. `VaeCodec` uses `Direct`
/// exclusively for the analytic (native-backend) path.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BetaBinomialDirect {
    pub n: u32,
    pub prec: u32,
    alpha: f64,
    beta: f64,
    /// (2^prec − (n+1)) / Σ unnormalized pmf.
    scale: f64,
}

impl BetaBinomialDirect {
    pub fn new(n: u32, alpha: f64, beta: f64, prec: u32) -> Self {
        // Same guard as from_params; additionally clamp to a range where
        // the unnormalized recurrence (anchored at p(0) = 1) cannot
        // overflow f64.
        let (alpha, beta) = if alpha.is_finite() && beta.is_finite() && alpha > 0.0 && beta > 0.0 {
            (alpha.clamp(1e-4, 200.0), beta.clamp(1e-4, 200.0))
        } else {
            (1.0, 1.0)
        };
        let nn = n as f64;
        let mut total = 1.0f64; // p(0) anchored at 1
        let mut cur = 1.0f64;
        for k in 0..n as usize {
            let kf = k as f64;
            cur *= ((nn - kf) * (kf + alpha)) / ((kf + 1.0) * (nn - kf - 1.0 + beta));
            total += cur;
        }
        let m = 1u64 << prec;
        let scale = (m - (n as u64 + 1)) as f64 / total;
        Self {
            n,
            prec,
            alpha,
            beta,
            scale,
        }
    }

    /// Batch-construct one codec per `(alpha, beta)` pixel pair — the
    /// whole-image form of [`BetaBinomialDirect::new`] and the ISSUE 5
    /// vectorization of the native pixel hot path.
    ///
    /// `new` is dominated by the `n`-step normalization recurrence, whose
    /// `cur *= ratio` / `total += cur` chain is strictly sequential *per
    /// pixel* — but pixels are independent, so the AVX2 path runs **four
    /// pixels' recurrences in four f64 lanes**, each lane executing
    /// exactly the scalar op sequence (sub/add/mul/div are lane-wise
    /// IEEE-754 ops, so every pixel's codec is bit-identical to its
    /// scalar construction; pinned by `new_batch_matches_new_bitwise`).
    /// This divides the dominant per-image construction cost by the lane
    /// count: the loop-carried multiply chain and the one divide per step
    /// now serve four pixels each.
    pub fn new_batch(n: u32, alphas: &[f32], betas: &[f32], prec: u32, out: &mut Vec<Self>) {
        assert_eq!(alphas.len(), betas.len(), "alpha/beta length mismatch");
        out.clear();
        out.reserve(alphas.len());
        let done = new_batch_simd(n, alphas, betas, prec, out);
        for p in done..alphas.len() {
            out.push(Self::new(n, alphas[p] as f64, betas[p] as f64, prec));
        }
    }

    /// `(start, freq)` of `sym`, walking the recurrence up to `sym + 1`.
    #[inline]
    pub fn interval(&self, sym: u32) -> (u32, u32) {
        let nn = self.n as f64;
        let m = 1u64 << self.prec;
        let mut cur = 1.0f64;
        let mut acc = 0.0f64;
        let mut g_prev = 0u64; // G(sym)
        for k in 0..=sym as usize {
            acc += cur;
            let g = if k as u32 == self.n {
                m
            } else {
                (acc * self.scale).round() as u64 + k as u64 + 1
            };
            if (k as u32) < sym {
                g_prev = g;
            } else {
                return (g_prev as u32, (g - g_prev) as u32);
            }
            let kf = k as f64;
            cur *= ((nn - kf) * (kf + self.alpha)) / ((kf + 1.0) * (nn - kf - 1.0 + self.beta));
        }
        unreachable!()
    }

    /// The prepared (division-free) form of `sym`'s interval, for the
    /// batch pixel path (`encode_all_prepared`).
    #[inline]
    pub fn prepared_interval(&self, sym: u32) -> crate::ans::PreparedInterval {
        let (start, freq) = self.interval(sym);
        crate::ans::PreparedInterval::new(start, freq, self.prec)
    }

    /// Find `(sym, start, freq)` containing `cf`, walking upward.
    #[inline]
    pub fn lookup(&self, cf: u32) -> (u32, u32, u32) {
        let nn = self.n as f64;
        let m = 1u64 << self.prec;
        let cf = cf as u64;
        let mut cur = 1.0f64;
        let mut acc = 0.0f64;
        let mut g_prev = 0u64;
        for k in 0..=self.n as usize {
            acc += cur;
            let g = if k as u32 == self.n {
                m
            } else {
                (acc * self.scale).round() as u64 + k as u64 + 1
            };
            if cf < g {
                return (k as u32, g_prev as u32, (g - g_prev) as u32);
            }
            g_prev = g;
            let kf = k as f64;
            cur *= ((nn - kf) * (kf + self.alpha)) / ((kf + 1.0) * (nn - kf - 1.0 + self.beta));
        }
        unreachable!("cf {cf} out of range")
    }
}

/// SIMD front half of [`BetaBinomialDirect::new_batch`]: build as many
/// leading codecs as the active vector path covers, returning the count
/// (always a multiple of the lane width; the caller finishes the tail
/// through the scalar constructor).
#[cfg(target_arch = "x86_64")]
fn new_batch_simd(
    n: u32,
    alphas: &[f32],
    betas: &[f32],
    prec: u32,
    out: &mut Vec<BetaBinomialDirect>,
) -> usize {
    if crate::simd::active() == crate::simd::Kernel::Avx2 {
        // SAFETY: AVX2 availability checked by dispatch.
        unsafe { new_batch_avx2(n, alphas, betas, prec, out) }
    } else {
        0
    }
}

#[cfg(not(target_arch = "x86_64"))]
fn new_batch_simd(
    _n: u32,
    _alphas: &[f32],
    _betas: &[f32],
    _prec: u32,
    _out: &mut Vec<BetaBinomialDirect>,
) -> usize {
    0
}

/// AVX2 lane-parallel body of [`BetaBinomialDirect::new_batch`]: four
/// pixels per iteration, each lane the exact scalar op sequence (see the
/// method docs). Returns how many leading pairs were consumed (a multiple
/// of 4); the dispatcher finishes the tail through the scalar
/// constructor, which is bit-identical by the same lane argument.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn new_batch_avx2(
    n: u32,
    alphas: &[f32],
    betas: &[f32],
    prec: u32,
    out: &mut Vec<BetaBinomialDirect>,
) -> usize {
    use core::arch::x86_64::*;
    let lanes = alphas.len() / 4 * 4;
    let nn = n as f64;
    let numer = ((1u64 << prec) - (n as u64 + 1)) as f64;
    let lo = _mm256_set1_pd(1e-4);
    let hi = _mm256_set1_pd(200.0);
    let one = _mm256_set1_pd(1.0);
    let zero = _mm256_setzero_pd();
    let inf = _mm256_set1_pd(f64::INFINITY);
    let mut i = 0;
    while i < lanes {
        let a = _mm256_cvtps_pd(_mm_loadu_ps(alphas.as_ptr().add(i)));
        let b = _mm256_cvtps_pd(_mm_loadu_ps(betas.as_ptr().add(i)));
        // Jointly valid ⟺ both parameters finite and > 0, exactly the
        // scalar guard (NaN fails the ordered compares).
        let va = _mm256_and_pd(
            _mm256_cmp_pd::<_CMP_GT_OQ>(a, zero),
            _mm256_cmp_pd::<_CMP_LT_OQ>(a, inf),
        );
        let vb = _mm256_and_pd(
            _mm256_cmp_pd::<_CMP_GT_OQ>(b, zero),
            _mm256_cmp_pd::<_CMP_LT_OQ>(b, inf),
        );
        let valid = _mm256_and_pd(va, vb);
        let av = _mm256_blendv_pd(one, _mm256_min_pd(_mm256_max_pd(a, lo), hi), valid);
        let bv = _mm256_blendv_pd(one, _mm256_min_pd(_mm256_max_pd(b, lo), hi), valid);
        // Four normalization recurrences, one per lane: the scalar-
        // computed per-step constants broadcast, then lane-wise
        // add/mul/div in the scalar evaluation order.
        let mut cur = one;
        let mut total = one;
        for k in 0..n as usize {
            let kf = k as f64;
            let num = _mm256_mul_pd(
                _mm256_set1_pd(nn - kf),
                _mm256_add_pd(_mm256_set1_pd(kf), av),
            );
            let den = _mm256_mul_pd(
                _mm256_set1_pd(kf + 1.0),
                _mm256_add_pd(_mm256_set1_pd(nn - kf - 1.0), bv),
            );
            cur = _mm256_mul_pd(cur, _mm256_div_pd(num, den));
            total = _mm256_add_pd(total, cur);
        }
        let (mut aa, mut bb, mut tt) = ([0.0f64; 4], [0.0f64; 4], [0.0f64; 4]);
        _mm256_storeu_pd(aa.as_mut_ptr(), av);
        _mm256_storeu_pd(bb.as_mut_ptr(), bv);
        _mm256_storeu_pd(tt.as_mut_ptr(), total);
        for l in 0..4 {
            out.push(BetaBinomialDirect {
                n,
                prec,
                alpha: aa[l],
                beta: bb[l],
                scale: numer / tt[l],
            });
        }
        i += 4;
    }
    lanes
}

impl SymbolCodec for BetaBinomialDirect {
    type Sym = u32;

    #[inline]
    fn push(&self, ans: &mut Ans, sym: u32) {
        debug_assert!(sym <= self.n);
        let (start, freq) = self.interval(sym);
        ans.push(start, freq, self.prec);
    }

    #[inline]
    fn pop(&self, ans: &mut Ans) -> u32 {
        ans.pop_with(self.prec, |cf| {
            let (sym, start, freq) = self.lookup(cf);
            (sym, start, freq)
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codecs::measure_bits;
    use crate::util::rng::Rng;

    #[test]
    fn roundtrip_params() {
        let mut rng = Rng::new(12);
        let mut ans = Ans::new(0);
        let mut trace = Vec::new();
        for _ in 0..300 {
            let a = 0.2 + rng.f64() * 20.0;
            let b = 0.2 + rng.f64() * 20.0;
            let c = BetaBinomial::from_params(255, a, b, 18);
            let s = rng.below(256) as u32;
            c.push(&mut ans, s);
            trace.push((c, s));
        }
        for (c, s) in trace.iter().rev() {
            assert_eq!(c.pop(&mut ans), *s);
        }
        assert!(ans.is_empty());
    }

    #[test]
    fn pmf_row_matches_params_construction() {
        // An f32 PMF row computed from the same (alpha, beta) should yield
        // a nearly identical codec (same quantization pipeline).
        let (a, b) = (3.5, 1.2);
        let row: Vec<f32> = (0..=255u32)
            .map(|k| beta_binomial_logpmf(k, 255, a, b).exp() as f32)
            .collect();
        let c1 = BetaBinomial::from_params(255, a, b, 16);
        let c2 = BetaBinomial::from_pmf_row(&row, 16);
        // Compare implied code lengths on a few symbols (f32 rounding can
        // shift interval boundaries by a mass unit or two).
        for s in [0usize, 1, 17, 128, 200, 255] {
            assert!(
                (c1.bits(s) - c2.bits(s)).abs() < 0.02,
                "sym {s}: {} vs {}",
                c1.bits(s),
                c2.bits(s)
            );
        }
    }

    #[test]
    fn scratch_row_construction_matches_allocating() {
        let (a, b) = (3.5, 1.2);
        let row: Vec<f32> = (0..=255u32)
            .map(|k| beta_binomial_logpmf(k, 255, a, b).exp() as f32)
            .collect();
        let mut buf = Vec::new();
        let c1 = BetaBinomial::from_pmf_row(&row, 16);
        let c2 = BetaBinomial::from_pmf_row_scratch(&row, 16, &mut buf);
        assert_eq!(c1.quantized(), c2.quantized());
        // The buffer is reusable across rows, including the degenerate
        // all-zero fallback.
        let zero = [0.0f32; 256];
        let c3 = BetaBinomial::from_pmf_row_scratch(&zero, 16, &mut buf);
        let c4 = BetaBinomial::from_pmf_row(&zero, 16);
        assert_eq!(c3.quantized(), c4.quantized());
    }

    #[test]
    fn degenerate_inputs_fall_back_to_uniform() {
        for (a, b) in [(f64::NAN, 1.0), (0.0, 2.0), (f64::INFINITY, 1.0)] {
            let c = BetaBinomial::from_params(255, a, b, 16);
            let mut ans = Ans::new(0);
            c.push(&mut ans, 255);
            assert_eq!(c.pop(&mut ans), 255);
        }
        let zero_row = vec![0.0f32; 256];
        let c = BetaBinomial::from_pmf_row(&zero_row, 16);
        let mut ans = Ans::new(0);
        c.push(&mut ans, 7);
        assert_eq!(c.pop(&mut ans), 7);
    }

    #[test]
    fn rate_matches_model_entropy() {
        // Code symbols sampled from BetaBin(255, 2, 5); rate ≈ entropy.
        let (a, b) = (2.0, 5.0);
        let pmf: Vec<f64> = (0..=255u32)
            .map(|k| beta_binomial_logpmf(k, 255, a, b).exp())
            .collect();
        let entropy: f64 = pmf.iter().filter(|&&p| p > 0.0).map(|p| -p * p.log2()).sum();
        // Inverse-CDF sampling.
        let mut rng = Rng::new(9);
        let cdf: Vec<f64> = pmf
            .iter()
            .scan(0.0, |acc, p| {
                *acc += p;
                Some(*acc)
            })
            .collect();
        let n = 20_000;
        let syms: Vec<u32> = (0..n)
            .map(|_| {
                let u = rng.f64();
                cdf.partition_point(|&c| c < u).min(255) as u32
            })
            .collect();
        let c = BetaBinomial::from_params(255, a, b, 18);
        let mut ans = Ans::new(0);
        let bits = measure_bits(&mut ans, |ans| {
            for &s in &syms {
                c.push(ans, s);
            }
        });
        let rate = bits / n as f64;
        assert!(
            (rate - entropy).abs() < 0.02 * entropy + 0.02,
            "rate={rate} entropy={entropy}"
        );
    }
}

#[cfg(test)]
mod direct_tests {
    use super::*;
    use crate::codecs::measure_bits;
    use crate::util::rng::Rng;

    #[test]
    fn direct_roundtrip_and_near_table_rate() {
        let mut rng = Rng::new(44);
        let mut ans = Ans::new(0);
        let mut trace = Vec::new();
        for _ in 0..300 {
            let a = 0.2 + rng.f64() * 20.0;
            let b = 0.2 + rng.f64() * 20.0;
            let c = BetaBinomialDirect::new(255, a, b, 18);
            let s = rng.below(256) as u32;
            c.push(&mut ans, s);
            trace.push((c, s));
        }
        for (c, s) in trace.iter().rev() {
            assert_eq!(c.pop(&mut ans), *s);
        }
        assert!(ans.is_empty());
    }

    #[test]
    fn direct_intervals_cover_full_mass() {
        let c = BetaBinomialDirect::new(255, 3.1, 0.7, 16);
        let mut pos = 0u32;
        for s in 0..=255u32 {
            let (start, freq) = c.interval(s);
            assert_eq!(start, pos, "intervals must tile");
            assert!(freq >= 1);
            pos = start + freq;
        }
        assert_eq!(pos as u64, 1u64 << 16);
        // lookup inverts interval at every boundary.
        for s in [0u32, 1, 17, 100, 254, 255] {
            let (start, freq) = c.interval(s);
            assert_eq!(c.lookup(start).0, s);
            assert_eq!(c.lookup(start + freq - 1).0, s);
        }
    }

    #[test]
    fn direct_rate_close_to_from_params() {
        let (a, b) = (2.0, 5.0);
        let direct = BetaBinomialDirect::new(255, a, b, 18);
        let table = BetaBinomial::from_params(255, a, b, 18);
        let mut rng = Rng::new(45);
        let syms: Vec<u32> = (0..2000).map(|_| rng.below(80) as u32).collect();
        let mut ans1 = Ans::new(0);
        let bits_direct = measure_bits(&mut ans1, |ans| {
            for &s in &syms {
                direct.push(ans, s);
            }
        });
        let mut ans2 = Ans::new(0);
        let bits_table = measure_bits(&mut ans2, |ans| {
            for &s in &syms {
                table.push(ans, s);
            }
        });
        assert!(
            (bits_direct - bits_table).abs() / bits_table < 0.001,
            "direct {bits_direct} vs table {bits_table}"
        );
    }

    /// The batched constructor must produce field-for-field identical
    /// codecs to per-pixel `new` — including the degenerate-parameter
    /// fallback and every remainder length — under the active kernel (the
    /// forced-scalar CI leg covers the scalar arm) and, when AVX2 is up,
    /// through the lane-parallel body directly.
    #[test]
    fn new_batch_matches_new_bitwise() {
        let mut rng = Rng::new(0xD1CE);
        for len in [0usize, 1, 3, 4, 5, 8, 63, 784] {
            let mut alphas: Vec<f32> = (0..len).map(|_| (rng.f64() * 30.0) as f32).collect();
            let mut betas: Vec<f32> = (0..len).map(|_| (rng.f64() * 30.0) as f32).collect();
            // Sprinkle degenerate and out-of-clamp-range values.
            for (i, v) in alphas.iter_mut().enumerate() {
                match i % 9 {
                    1 => *v = 0.0,
                    3 => *v = f32::NAN,
                    5 => *v = f32::INFINITY,
                    7 => *v = 5e5, // clamped to 200.0
                    _ => {}
                }
            }
            if len > 2 {
                betas[2] = -1.0;
                betas[len - 1] = 1e-9; // clamped to 1e-4
            }
            for prec in [14u32, 18] {
                let want: Vec<BetaBinomialDirect> = alphas
                    .iter()
                    .zip(betas.iter())
                    .map(|(&a, &b)| BetaBinomialDirect::new(255, a as f64, b as f64, prec))
                    .collect();
                let mut got = Vec::new();
                BetaBinomialDirect::new_batch(255, &alphas, &betas, prec, &mut got);
                assert_eq!(got, want, "len={len} prec={prec} (dispatched)");
                #[cfg(target_arch = "x86_64")]
                if crate::simd::available().contains(&crate::simd::Kernel::Avx2) {
                    let mut lanes = Vec::new();
                    // SAFETY: AVX2 presence just checked.
                    let done =
                        unsafe { super::new_batch_avx2(255, &alphas, &betas, prec, &mut lanes) };
                    assert_eq!(done, len / 4 * 4);
                    assert_eq!(lanes[..], want[..done], "len={len} prec={prec} (avx2)");
                }
            }
        }
    }

    #[test]
    fn direct_degenerate_params_fall_back_to_uniform() {
        for (a, b) in [(f64::NAN, 1.0), (0.0, 2.0), (f64::INFINITY, 1.0)] {
            let c = BetaBinomialDirect::new(255, a, b, 16);
            let mut ans = Ans::new(0);
            c.push(&mut ans, 255);
            assert_eq!(c.pop(&mut ans), 255);
        }
    }
}
