//! Distribution codecs: map model distributions onto quantized symbol
//! intervals that the [`crate::ans::Ans`] coder can push/pop.
//!
//! Every codec here is **deterministic**: given the same distribution
//! parameters it always produces the same quantized intervals, which is the
//! property BB-ANS needs for the encoder and decoder to stay in lockstep
//! (paper §2.4; DESIGN.md §6).

pub mod beta_binomial;
pub mod categorical;
pub mod gaussian;
pub mod quantize;
pub mod uniform;

use crate::ans::Ans;

/// A codec that can encode symbols onto / decode symbols from an ANS stack.
///
/// `push` and `pop` must be exact inverses: `pop(push(ans, s)) == s` with
/// the ANS state restored along the way.
pub trait SymbolCodec {
    type Sym;

    /// Encode `sym` onto the stack.
    fn push(&self, ans: &mut Ans, sym: Self::Sym);

    /// Decode a symbol from the stack (or sample it, if the stack runs into
    /// its clean-bit supply).
    fn pop(&self, ans: &mut Ans) -> Self::Sym;
}

/// Bits added to the message by running `f` against `ans` (negative if
/// `f` net-pops). Clean-bit draws are subtracted: treating the clean
/// supply as virtual pre-existing stack content makes a pop of a
/// probability-`q` symbol cost exactly `log q` (negative) regardless of
/// where its randomness came from.
pub fn measure_bits(ans: &mut Ans, f: impl FnOnce(&mut Ans)) -> f64 {
    let before = ans.frac_bit_len() - 32.0 * ans.clean_words_used() as f64;
    f(ans);
    let after = ans.frac_bit_len() - 32.0 * ans.clean_words_used() as f64;
    after - before
}

#[cfg(test)]
mod tests {
    use super::uniform::Uniform;
    use super::*;

    #[test]
    fn measure_bits_uniform_push() {
        let mut ans = Ans::new(0);
        let c = Uniform::new(8);
        let bits = measure_bits(&mut ans, |a| {
            for s in 0..100u32 {
                c.push(a, s % 256);
            }
        });
        assert!((bits - 800.0).abs() < 1.0, "bits={bits}");
    }
}
