//! Deterministic PMF/CDF quantization.
//!
//! ANS codes with integer frequencies summing to `2^prec`. Mapping a real
//! distribution onto such frequencies must (a) give every symbol a nonzero
//! frequency (a zero-frequency symbol would be unencodable — catastrophic
//! for lossless coding), (b) be exactly reproducible on the decoder, and
//! (c) waste as little rate as possible.
//!
//! We use the strictly-monotone CDF map (DESIGN.md §6):
//!
//! ```text
//! G(i) = round(F(i) · (M − K)) + i,   G(0) = 0, G(K) = M = 2^prec
//! ```
//!
//! where `F` is the real CDF over `K` symbols. `G` is strictly increasing,
//! so `freq(i) = G(i+1) − G(i) ≥ 1` always; the redundancy is at most
//! `log(M / (M − K))` bits per symbol — negligible for `K ≪ M`.

/// Densest precision at which [`DecodeLut::build`] uses a direct-index
/// table (`2^prec` u16 entries); above it a coarse bucket table is used.
pub const DENSE_LUT_MAX_PREC: u32 = 16;

/// Optional cumulative→symbol lookup table replacing the per-pop binary
/// search (ISSUE 2: the decode-side hot path).
///
/// * [`DecodeLut::Dense`] — one `u16` per mass unit; `lookup` is a single
///   indexed load. Build cost `O(2^prec)`, so it is reserved for
///   `prec ≤` [`DENSE_LUT_MAX_PREC`] and for distributions that decode
///   many symbols (opt-in via [`QuantizedCdf::build_lut`]).
/// * [`DecodeLut::Coarse`] — `cf >> shift` indexes a bucket holding the
///   first symbol whose interval intersects it; a short forward scan on
///   the cdf finishes the job. Build cost `O(K + buckets)`, expected scan
///   length `≤ K / buckets` (buckets ≈ 4K, capped at 2¹⁶).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum DecodeLut {
    Dense(Vec<u16>),
    Coarse { shift: u32, first: Vec<u32> },
}

impl DecodeLut {
    /// Pick the right variant for `prec` (dense at or below
    /// [`DENSE_LUT_MAX_PREC`], coarse above).
    pub fn build(cdf: &[u32], prec: u32) -> Self {
        if prec <= DENSE_LUT_MAX_PREC {
            Self::dense(cdf, prec)
        } else {
            Self::coarse(cdf, prec)
        }
    }

    /// Direct-index table: `lookup` is O(1) with no scan.
    pub fn dense(cdf: &[u32], prec: u32) -> Self {
        assert!(
            prec <= DENSE_LUT_MAX_PREC,
            "dense LUT at prec {prec} would need {} entries",
            1u64 << prec
        );
        let mut t = vec![0u16; 1usize << prec];
        for (s, w) in cdf.windows(2).enumerate() {
            t[w[0] as usize..w[1] as usize].fill(s as u16);
        }
        DecodeLut::Dense(t)
    }

    /// Bucket table + short scan: O(K) build, O(1) expected lookup.
    pub fn coarse(cdf: &[u32], prec: u32) -> Self {
        let k = cdf.len() - 1;
        // ~4 buckets per symbol, capped at 2^16 entries and at 2^prec.
        let bucket_bits = (((k.max(2) - 1).ilog2() + 3).min(16)).min(prec);
        let shift = prec - bucket_bits;
        let n_buckets = 1usize << bucket_bits;
        let mut first = Vec::with_capacity(n_buckets);
        let mut s = 0usize;
        for b in 0..n_buckets {
            let lo = (b as u64) << shift;
            while (cdf[s + 1] as u64) <= lo {
                s += 1;
            }
            first.push(s as u32);
        }
        DecodeLut::Coarse { shift, first }
    }

    /// The symbol whose interval contains `cf`. `cdf` must be the table
    /// this LUT was built from.
    #[inline]
    pub fn lookup(&self, cdf: &[u32], cf: u32) -> usize {
        match self {
            DecodeLut::Dense(t) => t[cf as usize] as usize,
            DecodeLut::Coarse { shift, first } => {
                let mut s = first[(cf >> shift) as usize] as usize;
                while cdf[s + 1] <= cf {
                    s += 1;
                }
                s
            }
        }
    }
}

/// Quantized distribution over `0..K` with total mass `2^prec`.
///
/// Equality compares the distribution (`cdf`, `prec`) only — the optional
/// decode LUT is derived data and never affects semantics.
#[derive(Debug, Clone)]
pub struct QuantizedCdf {
    /// Cumulative bounds; length K+1, `cdf[0] = 0`, `cdf[K] = 2^prec`.
    pub cdf: Vec<u32>,
    pub prec: u32,
    /// Optional O(1) cumulative→symbol table (see [`DecodeLut`]).
    lut: Option<DecodeLut>,
}

impl PartialEq for QuantizedCdf {
    fn eq(&self, other: &Self) -> bool {
        self.cdf == other.cdf && self.prec == other.prec
    }
}

impl Eq for QuantizedCdf {}

impl QuantizedCdf {
    /// Quantize a PMF (need not be normalized; must be non-negative with a
    /// positive sum and finite entries).
    pub fn from_pmf(pmf: &[f64], prec: u32) -> Self {
        let mut buf = pmf.to_vec();
        Self::from_pmf_in_place(&mut buf, prec)
    }

    /// [`QuantizedCdf::from_pmf`] consuming the buffer in place — the
    /// allocation-free form the per-pixel row path feeds its scratch
    /// through (ISSUE 5). The construction is split so its element-wise
    /// half vectorizes while staying bit-identical to the historical
    /// single loop:
    ///
    /// 1. a **sequential** in-place prefix sum (the running `acc` of the
    ///    old loop; its final entry is bitwise the old `pmf.iter().sum()`
    ///    because both perform the same left-to-right adds), then
    /// 2. the **element-wise** `G(i) = round(acc_i · scale) + i + 1`,
    ///    whose multiply+round runs through the SIMD-dispatched
    ///    [`crate::simd::scaled_round_half_away`] (exact round-half-away
    ///    emulation for the non-negative domain — see that module's docs).
    pub fn from_pmf_in_place(pmf: &mut [f64], prec: u32) -> Self {
        let k = pmf.len();
        assert!(k >= 1, "empty pmf");
        let m = 1u64 << prec;
        assert!(
            (k as u64) < m,
            "pmf has {k} symbols but precision {prec} provides only {m} mass units"
        );
        let mut acc = 0.0f64;
        for p in pmf.iter_mut() {
            debug_assert!(*p >= 0.0, "negative pmf entry {p}");
            acc += *p;
            *p = acc;
        }
        let total = acc;
        assert!(
            total > 0.0 && total.is_finite(),
            "pmf must have positive finite mass (total={total})"
        );
        let scale = (m - k as u64) as f64 / total;
        // Vectorized: prefix[i] ← round(prefix[i] · scale), half away
        // from zero. The last entry is pinned to m below, so skip it.
        crate::simd::scaled_round_half_away(&mut pmf[..k - 1], scale);
        let mut cdf = Vec::with_capacity(k + 1);
        cdf.push(0u32);
        for (i, &g) in pmf[..k - 1].iter().enumerate() {
            cdf.push((g as u64 + (i as u64 + 1)).min(m) as u32);
        }
        cdf.push(m as u32);
        // Strict monotonicity is guaranteed by construction; check in debug.
        debug_assert!(cdf.windows(2).all(|w| w[0] < w[1]), "non-monotone cdf");
        Self {
            cdf,
            prec,
            lut: None,
        }
    }

    /// Build the cumulative→symbol [`DecodeLut`] once (idempotent); every
    /// subsequent [`QuantizedCdf::lookup`] is O(1) instead of a binary
    /// search. Opt-in because the dense table costs `O(2^prec)` to build —
    /// worth it for distributions that decode many symbols, not for the
    /// per-pixel codecs built fresh for a single lookup.
    pub fn build_lut(&mut self) {
        if self.lut.is_none() {
            self.lut = Some(DecodeLut::build(&self.cdf, self.prec));
        }
    }

    /// Builder-style [`QuantizedCdf::build_lut`].
    pub fn with_lut(mut self) -> Self {
        self.build_lut();
        self
    }

    /// The built LUT, if any.
    #[inline]
    pub fn lut(&self) -> Option<&DecodeLut> {
        self.lut.as_ref()
    }

    #[inline]
    pub fn num_symbols(&self) -> usize {
        self.cdf.len() - 1
    }

    #[inline]
    pub fn start(&self, sym: usize) -> u32 {
        self.cdf[sym]
    }

    #[inline]
    pub fn freq(&self, sym: usize) -> u32 {
        self.cdf[sym + 1] - self.cdf[sym]
    }

    /// Find the symbol whose interval contains `cf`: O(1) through the
    /// [`DecodeLut`] when one was built, binary search otherwise.
    #[inline]
    pub fn lookup(&self, cf: u32) -> usize {
        debug_assert!((cf as u64) < (1u64 << self.prec));
        match &self.lut {
            Some(lut) => lut.lookup(&self.cdf, cf),
            None => self.lookup_binary(cf),
        }
    }

    /// The LUT-free binary search (kept as the reference the property
    /// tests pin the LUT against).
    #[inline]
    pub fn lookup_binary(&self, cf: u32) -> usize {
        // partition_point: first index where cdf[i] > cf, minus one.
        self.cdf.partition_point(|&c| c <= cf) - 1
    }

    /// Quantized probability of `sym`.
    pub fn prob(&self, sym: usize) -> f64 {
        self.freq(sym) as f64 / (1u64 << self.prec) as f64
    }

    /// Entropy (bits/symbol) of the quantized distribution.
    pub fn entropy(&self) -> f64 {
        (0..self.num_symbols())
            .map(|s| {
                let p = self.prob(s);
                if p > 0.0 {
                    -p * p.log2()
                } else {
                    0.0
                }
            })
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn uniform_pmf_quantizes_evenly() {
        let q = QuantizedCdf::from_pmf(&[1.0; 16], 12);
        assert_eq!(q.num_symbols(), 16);
        assert_eq!(q.cdf[0], 0);
        assert_eq!(q.cdf[16], 1 << 12);
        for s in 0..16 {
            assert_eq!(q.freq(s), 256);
        }
    }

    #[test]
    fn every_symbol_gets_nonzero_freq_even_with_tiny_mass() {
        // One huge spike and many ~zero entries.
        let mut pmf = vec![0.0f64; 256];
        pmf[100] = 1.0;
        let q = QuantizedCdf::from_pmf(&pmf, 16);
        for s in 0..256 {
            assert!(q.freq(s) >= 1, "symbol {s} has zero freq");
        }
        // The spike keeps nearly all the mass.
        assert!(q.prob(100) > 0.99);
    }

    #[test]
    fn lookup_inverts_intervals() {
        let mut rng = Rng::new(10);
        let pmf: Vec<f64> = (0..64).map(|_| rng.f64() + 1e-6).collect();
        let q = QuantizedCdf::from_pmf(&pmf, 14);
        for s in 0..q.num_symbols() {
            let st = q.start(s);
            let f = q.freq(s);
            assert_eq!(q.lookup(st), s);
            assert_eq!(q.lookup(st + f - 1), s);
        }
        assert_eq!(q.lookup(0), 0);
        assert_eq!(q.lookup((1 << 14) - 1), 63);
    }

    #[test]
    fn unnormalized_pmf_equivalent_to_normalized() {
        let pmf: Vec<f64> = vec![0.1, 0.4, 0.2, 0.3];
        let scaled: Vec<f64> = pmf.iter().map(|p| p * 37.5).collect();
        let a = QuantizedCdf::from_pmf(&pmf, 16);
        let b = QuantizedCdf::from_pmf(&scaled, 16);
        assert_eq!(a, b);
    }

    #[test]
    fn quantization_redundancy_is_small() {
        // KL(true || quantized) should be ~K/M-level for a smooth pmf.
        let k = 256;
        let pmf: Vec<f64> = (0..k)
            .map(|i| (-((i as f64 - 128.0) / 30.0).powi(2)).exp() + 1e-9)
            .collect();
        let total: f64 = pmf.iter().sum();
        let q = QuantizedCdf::from_pmf(&pmf, 18);
        let kl: f64 = (0..k)
            .map(|i| {
                let p = pmf[i] / total;
                if p > 0.0 {
                    p * (p / q.prob(i)).log2()
                } else {
                    0.0
                }
            })
            .sum();
        assert!(kl < 0.005, "quantization KL too large: {kl}");
    }

    #[test]
    fn dense_lut_agrees_with_binary_search_exhaustively() {
        let mut rng = Rng::new(21);
        let pmf: Vec<f64> = (0..200).map(|_| rng.f64() + 1e-7).collect();
        let q = QuantizedCdf::from_pmf(&pmf, 14).with_lut();
        assert!(matches!(q.lut(), Some(DecodeLut::Dense(_))));
        for cf in 0..(1u32 << 14) {
            assert_eq!(q.lookup(cf), q.lookup_binary(cf), "cf={cf}");
        }
    }

    #[test]
    fn coarse_lut_agrees_with_binary_search() {
        let mut rng = Rng::new(22);
        // Spiked pmf: crowds many symbols into few buckets (worst case
        // for the scan) while one bucket spans many mass units.
        let mut pmf: Vec<f64> = (0..300).map(|_| rng.f64() * 1e-6 + 1e-9).collect();
        pmf[137] = 1.0;
        let q = QuantizedCdf::from_pmf(&pmf, 20).with_lut();
        assert!(matches!(q.lut(), Some(DecodeLut::Coarse { .. })));
        // Every interval boundary, plus random probes.
        for s in 0..q.num_symbols() {
            for cf in [q.start(s), q.start(s) + q.freq(s) - 1] {
                assert_eq!(q.lookup(cf), s, "cf={cf}");
            }
        }
        for _ in 0..20_000 {
            let cf = rng.below(1 << 20) as u32;
            assert_eq!(q.lookup(cf), q.lookup_binary(cf), "cf={cf}");
        }
    }

    #[test]
    fn build_lut_is_idempotent_and_ignored_by_equality() {
        let pmf = [0.2, 0.5, 0.3];
        let plain = QuantizedCdf::from_pmf(&pmf, 12);
        let mut lutted = QuantizedCdf::from_pmf(&pmf, 12);
        lutted.build_lut();
        lutted.build_lut();
        assert_eq!(plain, lutted, "LUT must not affect distribution equality");
        assert!(plain.lut().is_none());
        assert!(lutted.lut().is_some());
    }

    /// The split prefix-sum + vectorized-round construction must equal the
    /// historical single loop bitwise, for every pmf shape, under the
    /// active kernel (CI's forced-scalar leg covers the scalar arm; the
    /// `simd` unit tests pin the variants against each other) — the
    /// guarantee that no stream, including PJRT table-path streams,
    /// changes a byte under ISSUE 5.
    #[test]
    fn split_construction_matches_historical_loop_bitwise() {
        fn historical(pmf: &[f64], prec: u32) -> Vec<u32> {
            let k = pmf.len();
            let m = 1u64 << prec;
            let total: f64 = pmf.iter().sum();
            let scale = (m - k as u64) as f64 / total;
            let mut cdf = vec![0u32];
            let mut acc = 0.0f64;
            for (i, &p) in pmf.iter().enumerate() {
                acc += p;
                let g = if i + 1 == k {
                    m
                } else {
                    (acc * scale).round() as u64 + (i as u64 + 1)
                };
                cdf.push(g.min(m) as u32);
            }
            cdf
        }
        let mut rng = Rng::new(0xC0F);
        for trial in 0..200 {
            let k = 1 + rng.below(400) as usize;
            let prec = (12 + rng.below(13) as u32).max((k as u32).ilog2() + 2);
            let pmf: Vec<f64> = (0..k)
                .map(|i| match trial % 4 {
                    0 => rng.f64() + 1e-9,
                    1 => 0.7f64.powi((i % 50) as i32),
                    2 => {
                        if i == k / 2 {
                            1e9
                        } else {
                            1e-12
                        }
                    }
                    _ => (i % 7) as f64, // exact zeros allowed
                })
                .collect();
            if pmf.iter().sum::<f64>() <= 0.0 {
                continue;
            }
            let want = historical(&pmf, prec);
            let q = QuantizedCdf::from_pmf(&pmf, prec);
            assert_eq!(q.cdf, want, "trial {trial} k={k} prec={prec}");
            let mut buf = pmf.clone();
            let q2 = QuantizedCdf::from_pmf_in_place(&mut buf, prec);
            assert_eq!(q2, q, "in-place construction diverged");
        }
    }

    #[test]
    #[should_panic(expected = "mass units")]
    fn too_many_symbols_for_precision_panics() {
        QuantizedCdf::from_pmf(&[1.0; 300], 8);
    }

    #[test]
    fn single_symbol_pmf() {
        let q = QuantizedCdf::from_pmf(&[5.0], 8);
        assert_eq!(q.num_symbols(), 1);
        assert_eq!(q.freq(0), 256);
        assert_eq!(q.lookup(17), 0);
    }
}
