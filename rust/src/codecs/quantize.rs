//! Deterministic PMF/CDF quantization.
//!
//! ANS codes with integer frequencies summing to `2^prec`. Mapping a real
//! distribution onto such frequencies must (a) give every symbol a nonzero
//! frequency (a zero-frequency symbol would be unencodable — catastrophic
//! for lossless coding), (b) be exactly reproducible on the decoder, and
//! (c) waste as little rate as possible.
//!
//! We use the strictly-monotone CDF map (DESIGN.md §6):
//!
//! ```text
//! G(i) = round(F(i) · (M − K)) + i,   G(0) = 0, G(K) = M = 2^prec
//! ```
//!
//! where `F` is the real CDF over `K` symbols. `G` is strictly increasing,
//! so `freq(i) = G(i+1) − G(i) ≥ 1` always; the redundancy is at most
//! `log(M / (M − K))` bits per symbol — negligible for `K ≪ M`.

/// Quantized distribution over `0..K` with total mass `2^prec`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct QuantizedCdf {
    /// Cumulative bounds; length K+1, `cdf[0] = 0`, `cdf[K] = 2^prec`.
    pub cdf: Vec<u32>,
    pub prec: u32,
}

impl QuantizedCdf {
    /// Quantize a PMF (need not be normalized; must be non-negative with a
    /// positive sum and finite entries).
    pub fn from_pmf(pmf: &[f64], prec: u32) -> Self {
        let k = pmf.len();
        assert!(k >= 1, "empty pmf");
        let m = 1u64 << prec;
        assert!(
            (k as u64) < m,
            "pmf has {k} symbols but precision {prec} provides only {m} mass units"
        );
        let total: f64 = pmf.iter().sum();
        assert!(
            total > 0.0 && total.is_finite(),
            "pmf must have positive finite mass (total={total})"
        );
        let scale = (m - k as u64) as f64 / total;
        let mut cdf = Vec::with_capacity(k + 1);
        cdf.push(0u32);
        let mut acc = 0.0f64;
        for (i, &p) in pmf.iter().enumerate() {
            debug_assert!(p >= 0.0, "negative pmf entry {p}");
            acc += p;
            let g = if i + 1 == k {
                m
            } else {
                (acc * scale).round() as u64 + (i as u64 + 1)
            };
            cdf.push(g.min(m) as u32);
        }
        // Strict monotonicity is guaranteed by construction; check in debug.
        debug_assert!(cdf.windows(2).all(|w| w[0] < w[1]), "non-monotone cdf");
        Self { cdf, prec }
    }

    #[inline]
    pub fn num_symbols(&self) -> usize {
        self.cdf.len() - 1
    }

    #[inline]
    pub fn start(&self, sym: usize) -> u32 {
        self.cdf[sym]
    }

    #[inline]
    pub fn freq(&self, sym: usize) -> u32 {
        self.cdf[sym + 1] - self.cdf[sym]
    }

    /// Find the symbol whose interval contains `cf` (binary search).
    #[inline]
    pub fn lookup(&self, cf: u32) -> usize {
        debug_assert!((cf as u64) < (1u64 << self.prec));
        // partition_point: first index where cdf[i] > cf, minus one.
        self.cdf.partition_point(|&c| c <= cf) - 1
    }

    /// Quantized probability of `sym`.
    pub fn prob(&self, sym: usize) -> f64 {
        self.freq(sym) as f64 / (1u64 << self.prec) as f64
    }

    /// Entropy (bits/symbol) of the quantized distribution.
    pub fn entropy(&self) -> f64 {
        (0..self.num_symbols())
            .map(|s| {
                let p = self.prob(s);
                if p > 0.0 {
                    -p * p.log2()
                } else {
                    0.0
                }
            })
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn uniform_pmf_quantizes_evenly() {
        let q = QuantizedCdf::from_pmf(&[1.0; 16], 12);
        assert_eq!(q.num_symbols(), 16);
        assert_eq!(q.cdf[0], 0);
        assert_eq!(q.cdf[16], 1 << 12);
        for s in 0..16 {
            assert_eq!(q.freq(s), 256);
        }
    }

    #[test]
    fn every_symbol_gets_nonzero_freq_even_with_tiny_mass() {
        // One huge spike and many ~zero entries.
        let mut pmf = vec![0.0f64; 256];
        pmf[100] = 1.0;
        let q = QuantizedCdf::from_pmf(&pmf, 16);
        for s in 0..256 {
            assert!(q.freq(s) >= 1, "symbol {s} has zero freq");
        }
        // The spike keeps nearly all the mass.
        assert!(q.prob(100) > 0.99);
    }

    #[test]
    fn lookup_inverts_intervals() {
        let mut rng = Rng::new(10);
        let pmf: Vec<f64> = (0..64).map(|_| rng.f64() + 1e-6).collect();
        let q = QuantizedCdf::from_pmf(&pmf, 14);
        for s in 0..q.num_symbols() {
            let st = q.start(s);
            let f = q.freq(s);
            assert_eq!(q.lookup(st), s);
            assert_eq!(q.lookup(st + f - 1), s);
        }
        assert_eq!(q.lookup(0), 0);
        assert_eq!(q.lookup((1 << 14) - 1), 63);
    }

    #[test]
    fn unnormalized_pmf_equivalent_to_normalized() {
        let pmf: Vec<f64> = vec![0.1, 0.4, 0.2, 0.3];
        let scaled: Vec<f64> = pmf.iter().map(|p| p * 37.5).collect();
        let a = QuantizedCdf::from_pmf(&pmf, 16);
        let b = QuantizedCdf::from_pmf(&scaled, 16);
        assert_eq!(a, b);
    }

    #[test]
    fn quantization_redundancy_is_small() {
        // KL(true || quantized) should be ~K/M-level for a smooth pmf.
        let k = 256;
        let pmf: Vec<f64> = (0..k)
            .map(|i| (-((i as f64 - 128.0) / 30.0).powi(2)).exp() + 1e-9)
            .collect();
        let total: f64 = pmf.iter().sum();
        let q = QuantizedCdf::from_pmf(&pmf, 18);
        let kl: f64 = (0..k)
            .map(|i| {
                let p = pmf[i] / total;
                if p > 0.0 {
                    p * (p / q.prob(i)).log2()
                } else {
                    0.0
                }
            })
            .sum();
        assert!(kl < 0.005, "quantization KL too large: {kl}");
    }

    #[test]
    #[should_panic(expected = "mass units")]
    fn too_many_symbols_for_precision_panics() {
        QuantizedCdf::from_pmf(&[1.0; 300], 8);
    }

    #[test]
    fn single_symbol_pmf() {
        let q = QuantizedCdf::from_pmf(&[5.0], 8);
        assert_eq!(q.num_symbols(), 1);
        assert_eq!(q.freq(0), 256);
        assert_eq!(q.lookup(17), 0);
    }
}
