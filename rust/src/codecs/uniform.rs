//! Uniform codec over `[0, 2^bits)`.
//!
//! This is the codec for the *prior* over max-entropy-discretized latents:
//! bucketing the prior at its own quantiles makes the discrete prior exactly
//! uniform, so prior coding has **zero** quantization loss (DESIGN.md §6).

use super::SymbolCodec;
use crate::ans::{Ans, PreparedInterval};

#[derive(Debug, Clone, Copy)]
pub struct Uniform {
    bits: u32,
}

impl Uniform {
    pub fn new(bits: u32) -> Self {
        assert!(bits >= 1 && bits <= crate::ans::MAX_PREC);
        Self { bits }
    }

    pub fn bits(&self) -> u32 {
        self.bits
    }
}

impl SymbolCodec for Uniform {
    type Sym = u32;

    #[inline]
    fn push(&self, ans: &mut Ans, sym: u32) {
        debug_assert!((sym as u64) < (1u64 << self.bits));
        // freq == 1 prepares without any division, so the prior path —
        // every latent dim of every image — is entirely division-free
        // (bit-identical to `ans.push(sym, 1, bits)`).
        ans.push_prepared(&PreparedInterval::new(sym, 1, self.bits));
    }

    #[inline]
    fn pop(&self, ans: &mut Ans) -> u32 {
        ans.pop_with(self.bits, |cf| (cf, cf, 1))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn roundtrip() {
        let c = Uniform::new(16);
        let mut rng = Rng::new(1);
        let syms: Vec<u32> = (0..10_000).map(|_| rng.below(1 << 16) as u32).collect();
        let mut ans = Ans::new(0);
        for &s in &syms {
            c.push(&mut ans, s);
        }
        for &s in syms.iter().rev() {
            assert_eq!(c.pop(&mut ans), s);
        }
        assert!(ans.is_empty());
    }

    #[test]
    fn costs_exactly_bits_per_symbol() {
        let c = Uniform::new(12);
        let mut ans = Ans::new(0);
        let n = 1000;
        let before = ans.frac_bit_len();
        let mut rng = Rng::new(2);
        for _ in 0..n {
            c.push(&mut ans, rng.below(1 << 12) as u32);
        }
        let bits = ans.frac_bit_len() - before;
        assert!((bits - (n * 12) as f64).abs() < 1.0, "bits={bits}");
    }

    #[test]
    fn pop_from_empty_samples_uniformly() {
        let c = Uniform::new(8);
        let mut ans = Ans::new(5);
        let n = 100_000;
        let mut counts = [0u32; 256];
        for _ in 0..n {
            counts[c.pop(&mut ans) as usize] += 1;
        }
        let expect = n as f64 / 256.0;
        for (s, &cnt) in counts.iter().enumerate() {
            assert!(
                (cnt as f64 - expect).abs() < 6.0 * expect.sqrt(),
                "symbol {s}: count {cnt} vs expected {expect}"
            );
        }
    }
}
