//! Discretized Gaussian codecs over the prior's max-entropy buckets.
//!
//! The latent space is partitioned into `N = 2^latent_bits` buckets of
//! equal mass under the standard Gaussian prior (paper §2.5.1, Appendix B):
//! bucket `i` spans `(probit(i/N), probit((i+1)/N))` with centre
//! `probit((i+0.5)/N)`.
//!
//! * Coding a latent **under the prior** is then exactly uniform —
//!   [`crate::codecs::uniform::Uniform`] with `latent_bits` bits.
//! * Coding **under the diagonal-Gaussian posterior** `N(μ, σ²)` uses this
//!   module: the posterior mass of bucket `i` is
//!   `Φ((e_{i+1}−μ)/σ) − Φ((e_i−μ)/σ)`, quantized with the strictly
//!   monotone map `G(i) = round(F(i)·(M−N)) + i` so every bucket stays
//!   codable no matter how sharp the posterior is.
//!
//! `G` is evaluated **lazily** (no 2^latent_bits tables): a push evaluates
//! two CDF points; a pop bisects on `G`, costing `O(latent_bits)` CDF
//! evaluations. This keeps 16-bit latents cheap — the paper notes gains
//! saturate by 16 bits/dim (§2.5.1), which `benches/ablations.rs` sweeps.

use std::collections::HashMap;
use std::sync::{Arc, Mutex, OnceLock};

use super::SymbolCodec;
use crate::ans::Ans;
use crate::util::math::{phi, probit};

/// Precomputed probit tables for one `latent_bits` (EXPERIMENTS.md §Perf
/// #2: edges are shared by every latent dim of every image, so they are
/// computed once per process and per bucket count).
#[derive(Debug)]
struct BucketTable {
    /// `edges[i]` = left edge of bucket i; length N+1 with ±∞ at the ends.
    edges: Vec<f64>,
    /// `centres[i]` = prior median of bucket i; length N.
    centres: Vec<f64>,
}

fn bucket_table(latent_bits: u32) -> Arc<BucketTable> {
    static CACHE: OnceLock<Mutex<HashMap<u32, Arc<BucketTable>>>> = OnceLock::new();
    let cache = CACHE.get_or_init(|| Mutex::new(HashMap::new()));
    let mut guard = cache.lock().unwrap();
    guard
        .entry(latent_bits)
        .or_insert_with(|| {
            let n = 1u32 << latent_bits;
            let mut edges = Vec::with_capacity(n as usize + 1);
            edges.push(f64::NEG_INFINITY);
            for i in 1..n {
                edges.push(probit(i as f64 / n as f64));
            }
            edges.push(f64::INFINITY);
            let centres = (0..n)
                .map(|i| probit((i as f64 + 0.5) / n as f64))
                .collect();
            Arc::new(BucketTable { edges, centres })
        })
        .clone()
}

/// Bucket geometry shared by prior and posterior: equal-prior-mass buckets.
///
/// Cheap to clone (shares the process-wide probit table). Tables are
/// cached for `latent_bits <= 16` (≤ 0.5 MiB); larger configurations
/// compute probits on demand.
#[derive(Debug, Clone)]
pub struct MaxEntropyBuckets {
    pub latent_bits: u32,
    table: Option<Arc<BucketTable>>,
}

impl PartialEq for MaxEntropyBuckets {
    fn eq(&self, other: &Self) -> bool {
        self.latent_bits == other.latent_bits
    }
}

impl MaxEntropyBuckets {
    pub fn new(latent_bits: u32) -> Self {
        assert!((1..=24).contains(&latent_bits));
        let table = (latent_bits <= 16).then(|| bucket_table(latent_bits));
        Self { latent_bits, table }
    }

    #[inline]
    pub fn num_buckets(&self) -> u32 {
        1 << self.latent_bits
    }

    /// Left edge of bucket `i` (−∞ for i = 0).
    #[inline]
    pub fn edge(&self, i: u32) -> f64 {
        let n = self.num_buckets();
        debug_assert!(i <= n);
        if let Some(t) = &self.table {
            return t.edges[i as usize];
        }
        if i == 0 {
            f64::NEG_INFINITY
        } else if i == n {
            f64::INFINITY
        } else {
            probit(i as f64 / n as f64)
        }
    }

    /// Centre (prior median) of bucket `i` — the value the decoder feeds to
    /// the generative network.
    #[inline]
    pub fn centre(&self, i: u32) -> f64 {
        let n = self.num_buckets();
        debug_assert!(i < n);
        if let Some(t) = &self.table {
            return t.centres[i as usize];
        }
        probit((i as f64 + 0.5) / n as f64)
    }

    /// Bucket containing latent value `y` (for encoding real samples).
    pub fn bucket_of(&self, y: f64) -> u32 {
        let n = self.num_buckets();
        let p = phi(y);
        // p in (0,1); floor(p*n) clamped to valid range.
        ((p * n as f64) as i64).clamp(0, n as i64 - 1) as u32
    }
}

/// Codec for a latent dimension under the posterior `N(μ, σ²)`, over the
/// prior's max-entropy buckets.
#[derive(Debug, Clone)]
pub struct DiscretizedGaussian {
    pub buckets: MaxEntropyBuckets,
    pub mu: f64,
    pub sigma: f64,
    /// Coding precision (mass = 2^prec). Must satisfy prec > latent_bits.
    pub prec: u32,
}

impl DiscretizedGaussian {
    pub fn new(buckets: MaxEntropyBuckets, mu: f64, sigma: f64, prec: u32) -> Self {
        assert!(prec <= crate::ans::MAX_PREC);
        assert!(
            prec > buckets.latent_bits,
            "precision {prec} must exceed latent_bits {} for nonzero freqs",
            buckets.latent_bits
        );
        assert!(sigma > 0.0 && sigma.is_finite(), "bad sigma {sigma}");
        assert!(mu.is_finite(), "bad mu {mu}");
        Self {
            buckets,
            mu,
            sigma,
            prec,
        }
    }

    /// Posterior CDF at the left edge of bucket `i`.
    #[inline]
    fn cdf(&self, i: u32) -> f64 {
        let e = self.buckets.edge(i);
        if e == f64::NEG_INFINITY {
            0.0
        } else if e == f64::INFINITY {
            1.0
        } else {
            phi((e - self.mu) / self.sigma)
        }
    }

    /// Strictly monotone quantized CDF `G(i)`; `G(0) = 0`, `G(N) = 2^prec`.
    #[inline]
    pub fn g(&self, i: u32) -> u64 {
        let n = self.buckets.num_buckets() as u64;
        let m = 1u64 << self.prec;
        if i == 0 {
            0
        } else if i as u64 == n {
            m
        } else {
            (self.cdf(i) * (m - n) as f64).round() as u64 + i as u64
        }
    }

    /// Interval of bucket `i`: `(start, freq)` out of `2^prec`.
    #[inline]
    pub fn interval(&self, i: u32) -> (u32, u32) {
        let lo = self.g(i);
        let hi = self.g(i + 1);
        debug_assert!(hi > lo);
        (lo as u32, (hi - lo) as u32)
    }

    /// Find the bucket whose interval contains `cf` by bisection on `G`.
    #[inline]
    pub fn bucket_for_cf(&self, cf: u32) -> u32 {
        let mut lo = 0u32; // G(lo) <= cf
        let mut hi = self.buckets.num_buckets(); // G(hi) > cf
        let cf = cf as u64;
        debug_assert!(self.g(hi) > cf);
        while hi - lo > 1 {
            let mid = lo + (hi - lo) / 2;
            if self.g(mid) <= cf {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        lo
    }
}

impl SymbolCodec for DiscretizedGaussian {
    type Sym = u32;

    #[inline]
    fn push(&self, ans: &mut Ans, sym: u32) {
        let (start, freq) = self.interval(sym);
        // Prepared push: the reciprocal build is independent work that
        // overlaps the two `phi` evaluations above, while the serial
        // coder-state update stays division-free. Bit-identical to
        // `ans.push(start, freq, prec)`.
        ans.push_prepared(&crate::ans::PreparedInterval::new(start, freq, self.prec));
    }

    #[inline]
    fn pop(&self, ans: &mut Ans) -> u32 {
        ans.pop_with(self.prec, |cf| {
            let i = self.bucket_for_cf(cf);
            let (start, freq) = self.interval(i);
            (i, start, freq)
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codecs::measure_bits;
    use crate::util::rng::Rng;

    #[test]
    fn bucket_geometry_is_consistent() {
        let b = MaxEntropyBuckets::new(8);
        assert_eq!(b.num_buckets(), 256);
        // Edges are increasing; centres sit inside their bucket.
        for i in 0..256u32 {
            let l = b.edge(i);
            let r = b.edge(i + 1);
            let c = b.centre(i);
            assert!(l < c && c < r, "bucket {i}: {l} {c} {r}");
            assert_eq!(b.bucket_of(c), i);
        }
        // Symmetric around zero.
        assert!((b.centre(127) + b.centre(128)).abs() < 1e-12);
    }

    #[test]
    fn g_is_strictly_monotone_even_for_sharp_posteriors() {
        let b = MaxEntropyBuckets::new(12);
        for (mu, sigma) in [(0.0, 1.0), (3.0, 0.01), (-7.5, 1e-6), (0.2, 50.0)] {
            let d = DiscretizedGaussian::new(b.clone(), mu, sigma, 24);
            let mut prev = d.g(0);
            assert_eq!(prev, 0);
            // Sample a subset of buckets plus the ends (full sweep is slow).
            let n = b.num_buckets();
            for i in 1..=n {
                if i < 64 || i > n - 64 || i % 61 == 0 || i == n {
                    let cur = d.g(i);
                    assert!(cur > prev, "G not strict at {i} (mu={mu}, sigma={sigma})");
                    prev = cur;
                }
            }
            assert_eq!(d.g(n), 1 << 24);
        }
    }

    #[test]
    fn roundtrip_various_posteriors() {
        let b = MaxEntropyBuckets::new(12);
        let mut rng = Rng::new(6);
        let mut ans = Ans::new(0);
        let mut pushed = Vec::new();
        for _ in 0..2000 {
            let mu = rng.normal() * 2.0;
            let sigma = 0.05 + rng.f64() * 2.0;
            let d = DiscretizedGaussian::new(b.clone(), mu, sigma, 24);
            let sym = rng.below(b.num_buckets() as u64) as u32;
            d.push(&mut ans, sym);
            pushed.push((d, sym));
        }
        for (d, sym) in pushed.iter().rev() {
            assert_eq!(d.pop(&mut ans), *sym);
        }
        assert!(ans.is_empty());
    }

    #[test]
    fn pop_samples_from_posterior() {
        // Sampling via pop on an empty stack should concentrate near mu.
        let b = MaxEntropyBuckets::new(12);
        let d = DiscretizedGaussian::new(b.clone(), 1.0, 0.1, 24);
        let mut ans = Ans::new(11);
        let n = 5000;
        let samples: Vec<f64> = (0..n).map(|_| b.centre(d.pop(&mut ans))).collect();
        // The quantization floor (1 mass unit per bucket, DESIGN.md §6)
        // gives the sampling distribution a faint heavy tail (~N/M of the
        // mass spread over all buckets), so estimate moments on the
        // 5-sigma-trimmed bulk.
        let bulk: Vec<f64> = samples
            .iter()
            .copied()
            .filter(|s| (s - 1.0).abs() < 0.5)
            .collect();
        assert!(bulk.len() as f64 > 0.998 * n as f64, "too many outliers");
        let mean = bulk.iter().sum::<f64>() / bulk.len() as f64;
        let var =
            bulk.iter().map(|s| (s - mean) * (s - mean)).sum::<f64>() / bulk.len() as f64;
        assert!((mean - 1.0).abs() < 0.02, "mean={mean}");
        assert!((var.sqrt() - 0.1).abs() < 0.01, "std={}", var.sqrt());
    }

    #[test]
    fn bitsback_identity_posterior_then_prior() {
        // The BB-ANS inner step for one latent dim: pop from posterior,
        // push to (uniform) prior; net bits = log(q/p) on average, and the
        // whole thing must invert exactly.
        use crate::codecs::uniform::Uniform;
        let b = MaxEntropyBuckets::new(12);
        let prior = Uniform::new(12);
        let mut ans = Ans::new(17);
        let mut trace = Vec::new();
        for k in 0..500 {
            let d = DiscretizedGaussian::new(
                b.clone(),
                (k % 7) as f64 - 3.0,
                0.2 + (k % 5) as f64 * 0.3,
                24,
            );
            let y = d.pop(&mut ans); // sample posterior (consumes bits)
            prior.push(&mut ans, y); // encode under prior (adds bits)
            trace.push((d, y));
        }
        // Invert: pop prior, push posterior.
        for (d, y) in trace.iter().rev() {
            let got = prior.pop(&mut ans);
            assert_eq!(got, *y);
            d.push(&mut ans, *y);
        }
        // After perfect inversion the coder is back to pristine state
        // except the clean words it borrowed are now explicit stream words.
        assert_eq!(ans.stream_len() as u64, ans.clean_words_used());
    }

    #[test]
    fn kl_cost_matches_theory() {
        // Net cost of (pop posterior, push prior) per dim ≈ KL(q || p_disc)
        // = E_q[log q(i)] + latent_bits.
        let b = MaxEntropyBuckets::new(10);
        let d = DiscretizedGaussian::new(b.clone(), 0.7, 0.3, 24);
        let mut ans = Ans::new(23);
        let prior = Uniform::new(10);
        use crate::codecs::uniform::Uniform;
        let n = 4000;
        let bits = measure_bits(&mut ans, |a| {
            for _ in 0..n {
                let y = d.pop(a);
                prior.push(a, y);
            }
        });
        // Analytic KL between the quantized posterior and uniform prior.
        let m = 1u64 << 24;
        let kl: f64 = (0..b.num_buckets())
            .map(|i| {
                let (_, f) = d.interval(i);
                let q = f as f64 / m as f64;
                if q > 0.0 {
                    q * (q.log2() + 10.0)
                } else {
                    0.0
                }
            })
            .sum();
        let rate = bits / n as f64;
        assert!(
            (rate - kl).abs() < 0.05 * kl.abs().max(0.2),
            "rate={rate} kl={kl}"
        );
    }
}
