//! Categorical codec over an explicit (quantized) PMF.
//!
//! The workhorse for likelihood coding: per-pixel Bernoulli and
//! beta-binomial codecs both reduce to a categorical over the pixel
//! alphabet with a deterministic quantization of the model's PMF.

use super::quantize::{DecodeLut, QuantizedCdf};
use super::SymbolCodec;
use crate::ans::{Ans, EntropyCoder, Interval, PreparedInterval, SymbolTable};

#[derive(Debug, Clone)]
pub struct Categorical {
    q: QuantizedCdf,
    /// Division-free encode table, built by [`Categorical::prepare`].
    prepared: Option<SymbolTable>,
}

impl Categorical {
    pub fn from_pmf(pmf: &[f64], prec: u32) -> Self {
        Self {
            q: QuantizedCdf::from_pmf(pmf, prec),
            prepared: None,
        }
    }

    /// [`Categorical::from_pmf`] consuming the buffer in place
    /// ([`QuantizedCdf::from_pmf_in_place`]) — the allocation-free form
    /// the per-pixel row path threads its scratch through.
    pub fn from_pmf_in_place(pmf: &mut [f64], prec: u32) -> Self {
        Self {
            q: QuantizedCdf::from_pmf_in_place(pmf, prec),
            prepared: None,
        }
    }

    pub fn from_quantized(q: QuantizedCdf) -> Self {
        Self { q, prepared: None }
    }

    /// Build the hot-path tables once: the prepared-symbol encode table
    /// (division-free pushes) and the decode LUT (O(1) cumulative→symbol).
    /// Worth it for any distribution that codes more symbols than its
    /// alphabet size; `encode_all`/`decode_all` also build throwaway
    /// tables on their own past that break-even, so `prepare` mainly helps
    /// callers that amortize one codec across many calls.
    pub fn prepare(mut self) -> Self {
        self.q.build_lut();
        if self.prepared.is_none() {
            self.prepared = Some(SymbolTable::from_cdf(&self.q.cdf, self.q.prec));
        }
        self
    }

    /// The prepared encode table, if [`Categorical::prepare`] was called.
    pub fn prepared(&self) -> Option<&SymbolTable> {
        self.prepared.as_ref()
    }

    /// Bernoulli over {0, 1} with P(1) = p.
    pub fn bernoulli(p: f64, prec: u32) -> Self {
        // Clamp away from degenerate endpoints; quantization keeps both
        // symbols codable regardless, but a NaN would poison the pmf.
        let p = if p.is_nan() { 0.5 } else { p.clamp(0.0, 1.0) };
        Self::from_pmf(&[1.0 - p, p], prec)
    }

    pub fn quantized(&self) -> &QuantizedCdf {
        &self.q
    }

    /// Ideal code length (bits) of `sym` under the quantized distribution.
    pub fn bits(&self, sym: usize) -> f64 {
        -self.q.prob(sym).log2()
    }

    /// Quantized interval of `sym`.
    #[inline]
    pub fn interval(&self, sym: usize) -> Interval {
        Interval {
            start: self.q.start(sym),
            freq: self.q.freq(sym),
        }
    }

    /// Encode a whole symbol sequence through any [`EntropyCoder`] —
    /// stack or interleaved multi-lane (paper §4.2 fast path). Always
    /// routes through the division-free prepared path (bit-identical to
    /// interval encoding): via the table from [`Categorical::prepare`]
    /// when present, via a throwaway table when the sequence is long
    /// enough to amortize one, and per-symbol otherwise.
    pub fn encode_all<C: EntropyCoder>(&self, coder: &mut C, syms: &[usize]) {
        self.encode_all_scratch(coder, syms, &mut Vec::new());
    }

    /// [`Categorical::encode_all`] with a caller-owned prepared-symbol
    /// buffer, so per-image/per-batch callers allocate nothing.
    pub fn encode_all_scratch<C: EntropyCoder>(
        &self,
        coder: &mut C,
        syms: &[usize],
        scratch: &mut Vec<PreparedInterval>,
    ) {
        if self.q.num_symbols() == 1 {
            // Single-symbol alphabet: the one interval carries the full
            // mass 2^prec, i.e. zero bits per symbol. `PreparedInterval`
            // represents that as an explicit no-op sentinel these days, so
            // this early return is just the cheap shortcut (skip the
            // gather and the per-symbol no-op pushes). `decode_all` needs
            // no twin guard: its update step is naturally the identity.
            debug_assert!(syms.iter().all(|&s| s == 0));
            return;
        }
        match &self.prepared {
            Some(t) => t.gather_into(syms, scratch),
            None if syms.len() >= self.q.num_symbols() => {
                SymbolTable::from_cdf(&self.q.cdf, self.q.prec).gather_into(syms, scratch)
            }
            None => {
                scratch.clear();
                scratch.extend(syms.iter().map(|&s| {
                    PreparedInterval::new(self.q.start(s), self.q.freq(s), self.q.prec)
                }));
            }
        }
        coder.encode_all_prepared(scratch, self.q.prec);
    }

    /// Decode `n` symbols through any [`EntropyCoder`] (inverse of
    /// [`Categorical::encode_all`], same symbol order). Symbol lookup is
    /// O(1) through the decode LUT when one is built (or when `n` is large
    /// enough to amortize a throwaway coarse table); binary search
    /// otherwise.
    pub fn decode_all<C: EntropyCoder>(&self, coder: &mut C, n: usize) -> Vec<usize> {
        if self.q.lut().is_some() || n < self.q.num_symbols() {
            coder.decode_all(n, self.q.prec, |cf| {
                let s = self.q.lookup(cf);
                (s, self.interval(s))
            })
        } else {
            // Coarse build is O(K); past the break-even it beats n binary
            // searches regardless of precision.
            let lut = DecodeLut::coarse(&self.q.cdf, self.q.prec);
            coder.decode_all(n, self.q.prec, |cf| {
                let s = lut.lookup(&self.q.cdf, cf);
                (s, self.interval(s))
            })
        }
    }
}

impl SymbolCodec for Categorical {
    type Sym = usize;

    #[inline]
    fn push(&self, ans: &mut Ans, sym: usize) {
        ans.push(self.q.start(sym), self.q.freq(sym), self.q.prec);
    }

    #[inline]
    fn pop(&self, ans: &mut Ans) -> usize {
        ans.pop_with(self.q.prec, |cf| {
            let s = self.q.lookup(cf);
            (s, self.q.start(s), self.q.freq(s))
        })
    }
}

/// Allocation-free Bernoulli codec (EXPERIMENTS.md §Perf #5).
///
/// Replicates [`Categorical::bernoulli`]'s quantization arithmetic
/// *operation-for-operation* (same `(1-p) + p` total, same rounding), so
/// the two produce bit-identical intervals — verified by test — while
/// skipping the two heap allocations per pixel.
#[derive(Debug, Clone, Copy)]
pub struct Bernoulli {
    /// Quantized boundary: interval of 0 is `[0, g1)`, of 1 `[g1, 2^prec)`.
    g1: u32,
    prec: u32,
}

impl Bernoulli {
    #[inline]
    pub fn new(p: f64, prec: u32) -> Self {
        let p = if p.is_nan() { 0.5 } else { p.clamp(0.0, 1.0) };
        // Mirror QuantizedCdf::from_pmf(&[1-p, p], prec) exactly.
        let m = 1u64 << prec;
        let p0 = 1.0 - p;
        let total = p0 + p;
        let scale = (m - 2) as f64 / total;
        let g1 = (p0 * scale).round() as u64 + 1;
        Self {
            g1: g1.min(m) as u32,
            prec,
        }
    }

    #[inline]
    pub fn interval(&self, sym: usize) -> (u32, u32) {
        let m = (1u64 << self.prec) as u32;
        if sym == 0 {
            (0, self.g1)
        } else {
            (self.g1, m - self.g1)
        }
    }

    /// Classify a cumulative value: `(symbol, start, freq)`.
    #[inline]
    pub fn lookup(&self, cf: u32) -> (usize, u32, u32) {
        let sym = (cf >= self.g1) as usize;
        let (start, freq) = self.interval(sym);
        (sym, start, freq)
    }

    /// The prepared (division-free) form of `sym`'s interval, for the
    /// batch pixel path (`encode_all_prepared`). The reciprocal build is
    /// independent of the coder state, so it pipelines with neighbouring
    /// pixels instead of serializing on the ANS head.
    #[inline]
    pub fn prepared_interval(&self, sym: usize) -> PreparedInterval {
        let (start, freq) = self.interval(sym);
        PreparedInterval::new(start, freq, self.prec)
    }
}

impl SymbolCodec for Bernoulli {
    type Sym = usize;

    #[inline]
    fn push(&self, ans: &mut Ans, sym: usize) {
        let (start, freq) = self.interval(sym);
        ans.push(start, freq, self.prec);
    }

    #[inline]
    fn pop(&self, ans: &mut Ans) -> usize {
        ans.pop_with(self.prec, |cf| self.lookup(cf))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codecs::measure_bits;
    use crate::util::rng::Rng;

    #[test]
    fn roundtrip_random_pmfs() {
        let mut rng = Rng::new(3);
        for trial in 0..20 {
            let k = 2 + rng.below(300) as usize;
            let pmf: Vec<f64> = (0..k).map(|_| rng.f64() + 1e-9).collect();
            let c = Categorical::from_pmf(&pmf, 18);
            let syms: Vec<usize> = (0..500).map(|_| rng.below(k as u64) as usize).collect();
            let mut ans = Ans::new(trial);
            for &s in &syms {
                c.push(&mut ans, s);
            }
            for &s in syms.iter().rev() {
                assert_eq!(c.pop(&mut ans), s);
            }
            assert!(ans.is_empty());
        }
    }

    #[test]
    fn bernoulli_rate_matches_entropy() {
        for p in [0.01, 0.2, 0.5, 0.9, 0.999] {
            let c = Categorical::bernoulli(p, 16);
            let mut rng = Rng::new(4);
            let n = 20_000;
            let syms: Vec<usize> = (0..n).map(|_| (rng.f64() < p) as usize).collect();
            let mut ans = Ans::new(0);
            let bits = measure_bits(&mut ans, |a| {
                for &s in &syms {
                    c.push(a, s);
                }
            });
            let h: f64 = -(p * p.log2() + (1.0 - p) * (1.0 - p).log2());
            let rate = bits / n as f64;
            // within 2% + small constant (sampling noise + quantization)
            assert!(
                (rate - h).abs() < 0.02 * h + 0.01,
                "p={p} rate={rate} entropy={h}"
            );
        }
    }

    #[test]
    fn bernoulli_handles_degenerate_p() {
        for p in [0.0, 1.0, f64::NAN] {
            let c = Categorical::bernoulli(p, 16);
            let mut ans = Ans::new(0);
            // Both symbols must be codable even at degenerate p.
            c.push(&mut ans, 0);
            c.push(&mut ans, 1);
            assert_eq!(c.pop(&mut ans), 1);
            assert_eq!(c.pop(&mut ans), 0);
        }
    }

    #[test]
    fn fast_bernoulli_bit_identical_to_categorical() {
        // The fast path must replicate Categorical::bernoulli exactly so
        // they can be mixed within one stream.
        let mut rng = Rng::new(91);
        for _ in 0..2000 {
            let p = rng.f64();
            for prec in [12u32, 16, 20] {
                let fast = Bernoulli::new(p, prec);
                let slow = Categorical::bernoulli(p, prec);
                for sym in 0..2 {
                    let (fs, ff) = fast.interval(sym);
                    assert_eq!(
                        (fs, ff),
                        (slow.q.start(sym), slow.q.freq(sym)),
                        "p={p} prec={prec} sym={sym}"
                    );
                }
            }
        }
        // Degenerate values too.
        for p in [0.0, 1.0, f64::NAN] {
            let fast = Bernoulli::new(p, 16);
            let slow = Categorical::bernoulli(p, 16);
            for sym in 0..2 {
                assert_eq!(
                    fast.interval(sym),
                    (slow.q.start(sym), slow.q.freq(sym))
                );
            }
        }
    }

    #[test]
    fn fast_bernoulli_roundtrip() {
        let mut rng = Rng::new(92);
        let mut ans = Ans::new(0);
        let mut trace = Vec::new();
        for _ in 0..5000 {
            let c = Bernoulli::new(rng.f64(), 16);
            let s = (rng.f64() < 0.5) as usize;
            c.push(&mut ans, s);
            trace.push((c, s));
        }
        for (c, s) in trace.iter().rev() {
            assert_eq!(c.pop(&mut ans), *s);
        }
        assert!(ans.is_empty());
    }

    #[test]
    fn encode_all_roundtrips_on_both_coders() {
        // The codec is written once against EntropyCoder and must behave
        // identically on the stack coder and every lane count.
        use crate::ans::interleaved::InterleavedAns;
        let mut rng = Rng::new(77);
        let pmf: Vec<f64> = (0..50).map(|_| rng.f64() + 1e-9).collect();
        let c = Categorical::from_pmf(&pmf, 16);
        let syms: Vec<usize> = (0..4001).map(|_| rng.below(50) as usize).collect();

        let mut stack = Ans::new(0);
        c.encode_all(&mut stack, &syms);
        assert_eq!(c.decode_all(&mut stack, syms.len()), syms);
        assert!(stack.is_empty());

        let mut lanes = InterleavedAns::<4>::new();
        c.encode_all(&mut lanes, &syms);
        assert_eq!(c.decode_all(&mut lanes, syms.len()), syms);
        assert!(lanes.is_pristine());
    }

    #[test]
    fn prepared_tables_do_not_change_bytes() {
        let mut rng = Rng::new(123);
        let pmf: Vec<f64> = (0..40).map(|_| rng.f64() + 1e-9).collect();
        let plain = Categorical::from_pmf(&pmf, 16);
        let fast = Categorical::from_pmf(&pmf, 16).prepare();
        assert!(fast.prepared().is_some());

        // Long (amortized-table branch) and short (per-symbol branch)
        // sequences, against the raw interval reference.
        for len in [3000usize, 5] {
            let syms: Vec<usize> = (0..len).map(|_| rng.below(40) as usize).collect();
            let ivs: Vec<Interval> = syms.iter().map(|&s| plain.interval(s)).collect();
            let mut reference = Ans::new(0);
            EntropyCoder::encode_all(&mut reference, &ivs, 16);

            let mut a = Ans::new(0);
            plain.encode_all(&mut a, &syms);
            let mut b = Ans::new(0);
            fast.encode_all(&mut b, &syms);
            assert_eq!(a.to_message(), reference.to_message(), "len={len}");
            assert_eq!(b.to_message(), reference.to_message(), "len={len}");

            // Decode back through both lookup paths (LUT and search).
            assert_eq!(fast.decode_all(&mut b, len), syms);
            assert_eq!(plain.decode_all(&mut a, len), syms);
            assert!(a.is_empty() && b.is_empty());
        }
    }

    #[test]
    fn single_symbol_alphabet_is_free() {
        // K = 1 (e.g. a one-state HMM's latent): every symbol carries zero
        // bits. Both the per-symbol and the batch encode paths must be
        // exact no-ops, and decode must invert them.
        let c = Categorical::from_pmf(&[1.0], 16);
        let mut ans = Ans::new(7);
        ans.push(3, 5, 12); // pre-existing content
        let before = ans.to_message();
        for _ in 0..50 {
            c.push(&mut ans, 0);
        }
        c.encode_all(&mut ans, &[0; 200]);
        assert_eq!(ans.to_message(), before, "k=1 coding must not change state");
        assert_eq!(c.decode_all(&mut ans, 200), vec![0usize; 200]);
        for _ in 0..50 {
            assert_eq!(c.pop(&mut ans), 0);
        }
        assert_eq!(ans.to_message(), before);
    }

    #[test]
    fn skewed_symbols_cost_expected_bits() {
        let c = Categorical::from_pmf(&[0.75, 0.25], 16);
        let mut ans = Ans::new(0);
        // Push many to average out renormalization granularity.
        let bits0 = measure_bits(&mut ans, |a| {
            for _ in 0..10_000 {
                c.push(a, 0);
            }
        });
        assert!((bits0 / 10_000.0 - 0.415).abs() < 0.01, "{}", bits0 / 10_000.0);
        let bits1 = measure_bits(&mut ans, |a| {
            for _ in 0..10_000 {
                c.push(a, 1);
            }
        });
        assert!((bits1 / 10_000.0 - 2.0).abs() < 0.01, "{}", bits1 / 10_000.0);
    }
}
