//! Self-contained micro-benchmark harness (criterion is unavailable in
//! this offline build). Used by every target under `rust/benches/`
//! (`harness = false`).
//!
//! Methodology: warm up, then run timed batches until both a minimum
//! duration and a minimum iteration count are reached; report mean ±
//! stddev of per-iteration time plus derived throughput.

use crate::util::timer::{fmt_duration, Stats};
use std::time::{Duration, Instant};

/// One benchmark measurement.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub iters: u64,
    pub mean: Duration,
    pub stddev: Duration,
    /// Optional user-supplied work units/iter (e.g. symbols) for rates.
    pub units_per_iter: f64,
}

impl Measurement {
    pub fn units_per_sec(&self) -> f64 {
        self.units_per_iter / self.mean.as_secs_f64()
    }
}

/// Benchmark runner with fixed time/iteration budgets.
pub struct Bench {
    pub warmup: Duration,
    pub measure: Duration,
    pub min_iters: u64,
    results: Vec<Measurement>,
}

impl Default for Bench {
    fn default() -> Self {
        Self::new()
    }
}

impl Bench {
    pub fn new() -> Self {
        // Budgets keep `cargo bench` minutes-scale across all targets; the
        // BBANS_BENCH_FAST env var shrinks them for smoke runs.
        let fast = std::env::var_os("BBANS_BENCH_FAST").is_some();
        Self {
            warmup: if fast {
                Duration::from_millis(50)
            } else {
                Duration::from_millis(300)
            },
            measure: if fast {
                Duration::from_millis(200)
            } else {
                Duration::from_secs(2)
            },
            min_iters: 3,
            results: Vec::new(),
        }
    }

    /// Time `f`, which performs `units` work units per call.
    pub fn run(&mut self, name: &str, units: f64, mut f: impl FnMut()) -> &Measurement {
        // Warmup.
        let w0 = Instant::now();
        while w0.elapsed() < self.warmup {
            f();
        }
        // Measure.
        let mut stats = Stats::new();
        let m0 = Instant::now();
        let mut iters = 0u64;
        while m0.elapsed() < self.measure || iters < self.min_iters {
            let t = Instant::now();
            f();
            stats.push(t.elapsed().as_secs_f64());
            iters += 1;
        }
        let m = Measurement {
            name: name.to_string(),
            iters,
            mean: Duration::from_secs_f64(stats.mean()),
            stddev: Duration::from_secs_f64(stats.stddev()),
            units_per_iter: units,
        };
        println!(
            "bench {:<44} {:>12}/iter ± {:>10}  ({} iters{})",
            m.name,
            fmt_duration(m.mean),
            fmt_duration(m.stddev),
            m.iters,
            if units > 0.0 {
                format!(", {:.3e} units/s", m.units_per_sec())
            } else {
                String::new()
            }
        );
        self.results.push(m);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[Measurement] {
        &self.results
    }
}

/// Black-box to stop the optimizer deleting benchmarked work.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Standard header for a paper-table bench binary.
pub fn table_header(title: &str) {
    println!();
    println!("================================================================");
    println!("{title}");
    println!("================================================================");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_reports() {
        std::env::set_var("BBANS_BENCH_FAST", "1");
        let mut b = Bench::new();
        b.warmup = Duration::from_millis(1);
        b.measure = Duration::from_millis(10);
        let mut acc = 0u64;
        let m = b.run("noop-ish", 100.0, || {
            acc = black_box(acc.wrapping_add(1));
        });
        assert!(m.iters >= 3);
        assert!(m.units_per_sec() > 0.0);
        assert_eq!(b.results().len(), 1);
    }
}
