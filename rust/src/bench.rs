//! Self-contained micro-benchmark harness (criterion is unavailable in
//! this offline build). Used by every target under `rust/benches/`
//! (`harness = false`).
//!
//! Methodology: warm up, then run timed batches until both a minimum
//! duration and a minimum iteration count are reached; report mean ±
//! stddev of per-iteration time plus derived throughput.
//!
//! ## Machine-readable trajectory (ISSUE 2)
//!
//! Targets that call [`Bench::finish`] emit their measurements as JSON so
//! perf PRs leave a recorded trajectory. Output is enabled by either:
//!
//! * `BBANS_BENCH_JSON=<path>` — write to an explicit path, or
//! * a `--json` argument (`cargo bench --bench ans -- --json`) — write
//!   `BENCH_<target>.json` at the repository root.
//!
//! Each record is `{name, iters, ns_per_op, ops_per_sec}`; `ops_per_sec`
//! is `null` for benches without a unit count.

use crate::util::json::Json;
use crate::util::timer::{fmt_duration, Stats};
use std::collections::BTreeMap;
use std::time::{Duration, Instant};

/// One benchmark measurement.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub iters: u64,
    pub mean: Duration,
    pub stddev: Duration,
    /// Optional user-supplied work units/iter (e.g. symbols) for rates.
    pub units_per_iter: f64,
}

impl Measurement {
    pub fn units_per_sec(&self) -> f64 {
        self.units_per_iter / self.mean.as_secs_f64()
    }

    /// Mean time per work unit in nanoseconds (per iteration when no unit
    /// count was supplied).
    pub fn ns_per_op(&self) -> f64 {
        let units = if self.units_per_iter > 0.0 {
            self.units_per_iter
        } else {
            1.0
        };
        self.mean.as_secs_f64() * 1e9 / units
    }
}

/// Benchmark runner with fixed time/iteration budgets.
pub struct Bench {
    pub warmup: Duration,
    pub measure: Duration,
    pub min_iters: u64,
    results: Vec<Measurement>,
    /// Derived scalars recorded alongside the measurements (knee points,
    /// suggested knobs, rates) — see [`Bench::annotate`].
    annotations: BTreeMap<String, f64>,
}

impl Default for Bench {
    fn default() -> Self {
        Self::new()
    }
}

impl Bench {
    pub fn new() -> Self {
        // Budgets keep `cargo bench` minutes-scale across all targets; the
        // BBANS_BENCH_FAST env var shrinks them for smoke runs.
        let fast = std::env::var_os("BBANS_BENCH_FAST").is_some();
        Self {
            warmup: if fast {
                Duration::from_millis(50)
            } else {
                Duration::from_millis(300)
            },
            measure: if fast {
                Duration::from_millis(200)
            } else {
                Duration::from_secs(2)
            },
            min_iters: 3,
            results: Vec::new(),
            annotations: BTreeMap::new(),
        }
    }

    /// Record a derived scalar into the JSON trajectory under
    /// `"annotations"` — for values that are conclusions rather than raw
    /// timings (a throughput knee, a suggested chunk size, a measured
    /// bits/dim).
    pub fn annotate(&mut self, key: &str, value: f64) {
        self.annotations.insert(key.to_string(), value);
    }

    /// Time `f`, which performs `units` work units per call.
    pub fn run(&mut self, name: &str, units: f64, mut f: impl FnMut()) -> &Measurement {
        // Warmup.
        let w0 = Instant::now();
        while w0.elapsed() < self.warmup {
            f();
        }
        // Measure.
        let mut stats = Stats::new();
        let m0 = Instant::now();
        let mut iters = 0u64;
        while m0.elapsed() < self.measure || iters < self.min_iters {
            let t = Instant::now();
            f();
            stats.push(t.elapsed().as_secs_f64());
            iters += 1;
        }
        let m = Measurement {
            name: name.to_string(),
            iters,
            mean: Duration::from_secs_f64(stats.mean()),
            stddev: Duration::from_secs_f64(stats.stddev()),
            units_per_iter: units,
        };
        println!(
            "bench {:<44} {:>12}/iter ± {:>10}  ({} iters{})",
            m.name,
            fmt_duration(m.mean),
            fmt_duration(m.stddev),
            m.iters,
            if units > 0.0 {
                format!(", {:.3e} units/s", m.units_per_sec())
            } else {
                String::new()
            }
        );
        self.results.push(m);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[Measurement] {
        &self.results
    }

    /// Serialize all measurements as the `BENCH_*.json` trajectory format.
    pub fn to_json(&self, target: &str) -> Json {
        let results: Vec<Json> = self
            .results
            .iter()
            .map(|m| {
                let mut rec = BTreeMap::new();
                rec.insert("name".to_string(), Json::Str(m.name.clone()));
                rec.insert("iters".to_string(), Json::Num(m.iters as f64));
                rec.insert("ns_per_op".to_string(), Json::Num(m.ns_per_op()));
                rec.insert(
                    "ops_per_sec".to_string(),
                    if m.units_per_iter > 0.0 {
                        Json::Num(m.units_per_sec())
                    } else {
                        Json::Null
                    },
                );
                Json::Obj(rec)
            })
            .collect();
        let mut top = BTreeMap::new();
        top.insert("target".to_string(), Json::Str(target.to_string()));
        top.insert(
            "fast_mode".to_string(),
            Json::Bool(std::env::var_os("BBANS_BENCH_FAST").is_some()),
        );
        top.insert("results".to_string(), Json::Arr(results));
        let ann: BTreeMap<String, Json> = self
            .annotations
            .iter()
            .map(|(k, v)| (k.clone(), Json::Num(*v)))
            .collect();
        top.insert("annotations".to_string(), Json::Obj(ann));
        Json::Obj(top)
    }

    /// Write the JSON trajectory if requested (see the module docs):
    /// `BBANS_BENCH_JSON=<path>` wins; otherwise a `--json` CLI argument
    /// writes `BENCH_<target>.json` at the repository root. Call once at
    /// the end of a bench target's `main`. Panics on I/O failure so CI
    /// fails loudly rather than silently dropping the trajectory.
    pub fn finish(&self, target: &str) {
        let path = match std::env::var_os("BBANS_BENCH_JSON") {
            Some(p) => std::path::PathBuf::from(p),
            None => {
                if !std::env::args().any(|a| a == "--json") {
                    return;
                }
                // CARGO_MANIFEST_DIR is rust/; the trajectory lives at the
                // repository root next to CHANGES.md.
                std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
                    .parent()
                    .expect("crate dir has a parent")
                    .join(format!("BENCH_{target}.json"))
            }
        };
        let body = format!("{}\n", self.to_json(target));
        std::fs::write(&path, body)
            .unwrap_or_else(|e| panic!("writing bench JSON {}: {e}", path.display()));
        println!("bench: wrote {}", path.display());
    }
}

/// Black-box to stop the optimizer deleting benchmarked work.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Standard header for a paper-table bench binary.
pub fn table_header(title: &str) {
    println!();
    println!("================================================================");
    println!("{title}");
    println!("================================================================");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_trajectory_parses_and_writes() {
        let mut b = Bench::new();
        b.warmup = Duration::from_millis(1);
        b.measure = Duration::from_millis(5);
        let mut acc = 0u64;
        b.run("with-units", 10.0, || {
            acc = black_box(acc.wrapping_add(1));
        });
        b.run("no-units", 0.0, || {
            acc = black_box(acc.wrapping_add(1));
        });
        b.annotate("knee", 64.0);

        let parsed = Json::parse(&b.to_json("unit").to_string()).unwrap();
        let ann = parsed.get("annotations").unwrap();
        assert_eq!(ann.get("knee").unwrap().as_f64().unwrap(), 64.0);
        assert_eq!(parsed.get("target").unwrap().as_str().unwrap(), "unit");
        let results = match parsed.get("results").unwrap() {
            Json::Arr(a) => a,
            other => panic!("results not an array: {other:?}"),
        };
        assert_eq!(results.len(), 2);
        assert_eq!(
            results[0].get("name").unwrap().as_str().unwrap(),
            "with-units"
        );
        assert!(results[0].get("ops_per_sec").unwrap().as_f64().unwrap() > 0.0);
        assert!(results[0].get("ns_per_op").unwrap().as_f64().unwrap() > 0.0);
        assert_eq!(*results[1].get("ops_per_sec").unwrap(), Json::Null);

        // finish() honours an explicit BBANS_BENCH_JSON path.
        let path =
            std::env::temp_dir().join(format!("bbans_bench_test_{}.json", std::process::id()));
        std::env::set_var("BBANS_BENCH_JSON", &path);
        b.finish("unit");
        std::env::remove_var("BBANS_BENCH_JSON");
        let text = std::fs::read_to_string(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        let reread = Json::parse(text.trim()).unwrap();
        assert_eq!(reread.get("target").unwrap().as_str().unwrap(), "unit");
    }

    #[test]
    fn bench_runs_and_reports() {
        std::env::set_var("BBANS_BENCH_FAST", "1");
        let mut b = Bench::new();
        b.warmup = Duration::from_millis(1);
        b.measure = Duration::from_millis(10);
        let mut acc = 0u64;
        let m = b.run("noop-ish", 100.0, || {
            acc = black_box(acc.wrapping_add(1));
        });
        assert!(m.iters >= 3);
        assert!(m.units_per_sec() > 0.0);
        assert_eq!(b.results().len(), 1);
    }
}
