//! Self-delimiting page frames — the integrity layer under paged
//! container formats (BBC4).
//!
//! A frame is `[magic | body_len u32 | page header | payload | crc32]`.
//! Three properties make damaged files recoverable page-by-page:
//!
//! * **self-delimiting** — `body_len` lets a reader skip a frame without
//!   understanding its payload, so one parser bug or corrupt page never
//!   desynchronizes the rest of the file;
//! * **integrity-checked** — the CRC-32 covers everything from the length
//!   field through the payload, so a flipped bit anywhere in the frame
//!   (including the length itself) is detected, never silently decoded;
//! * **resynchronizable** — the leading [`PAGE_MAGIC`] is *excluded* from
//!   the CRC, so a reader can re-find page boundaries after a torn region
//!   by scanning for the magic, and an index-guided reader can recover a
//!   page whose magic bytes themselves were damaged (the CRC still
//!   vouches for the body).
//!
//! The ANS payload gives no integrity signal at all — any bit pattern is
//! a decodable state — which is why this layer exists: without it a
//! single flipped bit silently corrupts every image in the container.

use crate::util::crc32;

pub mod stream;

/// Leading bytes of every page frame. Deliberately non-ASCII so runs of
/// text or zeros in headers/payloads cannot alias a frame start.
pub const PAGE_MAGIC: [u8; 4] = [0xB4, 0x50, 0x47, 0x1A]; // ´PG␚

/// Fixed page-header bytes inside the body: index, first_image,
/// num_images (u32 LE each).
pub const PAGE_HEADER_LEN: usize = 12;

/// Frame bytes beyond the payload: magic + body_len + header + crc.
pub const FRAME_OVERHEAD: usize = 4 + 4 + PAGE_HEADER_LEN + 4;

/// Cap on `body_len` so a corrupted length field cannot demand an absurd
/// skip or allocation (matches the wire protocol's 256 MiB frame cap).
pub const MAX_BODY: usize = 256 << 20;

/// One page: a self-contained slice of the dataset plus its chain bytes.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PageFrame {
    /// Position of this page in the container's page sequence (also the
    /// chunk index that seeds the page's clean-bit supply).
    pub index: u32,
    /// Global index of the first image coded in this page.
    pub first_image: u32,
    /// Number of images coded in this page.
    pub num_images: u32,
    /// Opaque payload (a serialized ANS message).
    pub payload: Vec<u8>,
}

impl PageFrame {
    /// Serialized size of this frame.
    pub fn byte_len(&self) -> usize {
        FRAME_OVERHEAD + self.payload.len()
    }

    /// Append the frame to `out`: magic, body length, header, payload,
    /// then a CRC-32 over body length + header + payload.
    pub fn write_to(&self, out: &mut Vec<u8>) {
        let body_len = (PAGE_HEADER_LEN + self.payload.len()) as u32;
        out.extend_from_slice(&PAGE_MAGIC);
        let crc_from = out.len();
        out.extend_from_slice(&body_len.to_le_bytes());
        out.extend_from_slice(&self.index.to_le_bytes());
        out.extend_from_slice(&self.first_image.to_le_bytes());
        out.extend_from_slice(&self.num_images.to_le_bytes());
        out.extend_from_slice(&self.payload);
        let crc = crc32::hash(&out[crc_from..]);
        out.extend_from_slice(&crc.to_le_bytes());
    }

    /// The CRC this frame serializes with (what a trailer index records).
    pub fn crc(&self) -> u32 {
        let mut h = crc32::Hasher::new();
        let body_len = (PAGE_HEADER_LEN + self.payload.len()) as u32;
        h.update(&body_len.to_le_bytes());
        h.update(&self.index.to_le_bytes());
        h.update(&self.first_image.to_le_bytes());
        h.update(&self.num_images.to_le_bytes());
        h.update(&self.payload);
        h.finalize()
    }
}

/// Outcome of reading one frame at a byte offset.
#[derive(Debug)]
pub enum FrameRead {
    /// A valid frame; `next` is the offset one past its last byte.
    Ok { frame: PageFrame, next: usize },
    /// The bytes at the offset do not start with [`PAGE_MAGIC`].
    NoMagic,
    /// Magic and length are present but the frame runs past the end of
    /// the buffer — the file was truncated mid-frame.
    Truncated { need: usize, have: usize },
    /// The frame is structurally present but fails validation; `detail`
    /// names the mismatch (CRC values, implausible length).
    Damaged { detail: String },
}

/// Read one frame starting exactly at `pos`, magic included.
pub fn read_frame(b: &[u8], pos: usize) -> FrameRead {
    if pos + 4 > b.len() || b[pos..pos + 4] != PAGE_MAGIC {
        return FrameRead::NoMagic;
    }
    read_frame_body(b, pos)
}

/// Read the frame body at `pos` **without** checking the magic — the
/// index-guided recovery path, where the trailer index vouches for the
/// offset and the CRC vouches for the body even if the magic bytes were
/// damaged.
pub fn read_frame_body(b: &[u8], pos: usize) -> FrameRead {
    let body_at = pos + 4;
    if body_at + 4 > b.len() {
        return FrameRead::Truncated {
            need: body_at + 4,
            have: b.len(),
        };
    }
    let body_len = u32::from_le_bytes(b[body_at..body_at + 4].try_into().unwrap()) as usize;
    if !(PAGE_HEADER_LEN..=MAX_BODY).contains(&body_len) {
        return FrameRead::Damaged {
            detail: format!("implausible page body length {body_len}"),
        };
    }
    let end = body_at + 4 + body_len + 4; // len field + body + crc
    if end > b.len() {
        return FrameRead::Truncated {
            need: end,
            have: b.len(),
        };
    }
    let computed = crc32::hash(&b[body_at..end - 4]);
    let stored = u32::from_le_bytes(b[end - 4..end].try_into().unwrap());
    if computed != stored {
        return FrameRead::Damaged {
            detail: format!("page CRC mismatch: stored {stored:#010x}, computed {computed:#010x}"),
        };
    }
    let h = body_at + 4;
    let frame = PageFrame {
        index: u32::from_le_bytes(b[h..h + 4].try_into().unwrap()),
        first_image: u32::from_le_bytes(b[h + 4..h + 8].try_into().unwrap()),
        num_images: u32::from_le_bytes(b[h + 8..h + 12].try_into().unwrap()),
        payload: b[h + PAGE_HEADER_LEN..end - 4].to_vec(),
    };
    FrameRead::Ok { frame, next: end }
}

/// Find the next possible frame start at or after `from` (the salvage
/// scanner's resync step after a torn region).
pub fn find_magic(b: &[u8], from: usize) -> Option<usize> {
    if from >= b.len() {
        return None;
    }
    b[from..]
        .windows(4)
        .position(|w| w == PAGE_MAGIC)
        .map(|p| from + p)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> PageFrame {
        PageFrame {
            index: 3,
            first_image: 42,
            num_images: 7,
            payload: vec![0xDE, 0xAD, 0xBE, 0xEF, 0x00, 0x11],
        }
    }

    #[test]
    fn frame_roundtrip() {
        let f = sample();
        let mut buf = Vec::new();
        f.write_to(&mut buf);
        assert_eq!(buf.len(), f.byte_len());
        match read_frame(&buf, 0) {
            FrameRead::Ok { frame, next } => {
                assert_eq!(frame, f);
                assert_eq!(next, buf.len());
            }
            other => panic!("expected Ok, got {other:?}"),
        }
    }

    #[test]
    fn crc_matches_serialized_frame() {
        let f = sample();
        let mut buf = Vec::new();
        f.write_to(&mut buf);
        let stored = u32::from_le_bytes(buf[buf.len() - 4..].try_into().unwrap());
        assert_eq!(stored, f.crc());
    }

    #[test]
    fn every_flipped_bit_is_detected() {
        let f = sample();
        let mut buf = Vec::new();
        f.write_to(&mut buf);
        // Any single bit flip anywhere in the frame must be caught: the
        // magic flips to NoMagic, everything else to Damaged/Truncated.
        for byte in 0..buf.len() {
            for bit in 0..8 {
                let mut bad = buf.clone();
                bad[byte] ^= 1 << bit;
                assert!(
                    !matches!(read_frame(&bad, 0), FrameRead::Ok { .. }),
                    "flip at byte {byte} bit {bit} went undetected"
                );
            }
        }
    }

    #[test]
    fn truncation_at_every_boundary_is_detected() {
        let f = sample();
        let mut buf = Vec::new();
        f.write_to(&mut buf);
        for cut in 0..buf.len() {
            assert!(
                !matches!(read_frame(&buf[..cut], 0), FrameRead::Ok { .. }),
                "truncation to {cut} bytes went undetected"
            );
        }
    }

    #[test]
    fn body_read_recovers_smashed_magic() {
        let f = sample();
        let mut buf = Vec::new();
        f.write_to(&mut buf);
        buf[0] = 0x00; // damage the magic only
        assert!(matches!(read_frame(&buf, 0), FrameRead::NoMagic));
        match read_frame_body(&buf, 0) {
            FrameRead::Ok { frame, .. } => assert_eq!(frame, f),
            other => panic!("expected body recovery, got {other:?}"),
        }
    }

    #[test]
    fn find_magic_resyncs_past_garbage() {
        let f = sample();
        let mut buf = vec![0xFF; 9];
        f.write_to(&mut buf);
        assert_eq!(find_magic(&buf, 0), Some(9));
        assert_eq!(find_magic(&buf, 10), None);
        assert_eq!(find_magic(&[], 0), None);
    }

    #[test]
    fn implausible_length_is_damaged_not_panic() {
        let f = sample();
        let mut buf = Vec::new();
        f.write_to(&mut buf);
        buf[4..8].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(matches!(read_frame(&buf, 0), FrameRead::Damaged { .. }));
        buf[4..8].copy_from_slice(&0u32.to_le_bytes());
        assert!(matches!(read_frame(&buf, 0), FrameRead::Damaged { .. }));
    }
}
