//! Crash-consistent streaming primitives: durable append media and the
//! progress journal that makes an interrupted page-stream encode
//! resumable.
//!
//! The BBC4 streaming writer ([`crate::bbans::bbc4::Bbc4StreamWriter`])
//! appends self-delimiting page frames to a **data medium** and, after
//! every page becomes durable, commits one fixed-size CRC'd record to a
//! sidecar **journal medium**. The ordering invariant the whole recovery
//! story rests on:
//!
//! > a journal record is appended only after the bytes it describes have
//! > been `sync`ed on the data medium.
//!
//! So after a power cut the journal can *lag* the data (the last page was
//! durable but its record was not yet written, or the record itself is
//! torn) but can never *lead* it — a journal claiming more pages than the
//! data file holds is evidence of real data loss, not a normal crash.
//! Resume therefore trusts a frame-by-frame scan of the data file as the
//! source of truth and uses the journal as a cross-check.
//!
//! Journal record layout (little-endian, [`JOURNAL_RECORD_LEN`] bytes):
//!
//! ```text
//! JOURNAL_MAGIC (4) | pages_done u32 | images_done u32
//! bytes_written u64 | last_crc u32   | record_crc u32
//! ```
//!
//! `bytes_written` is the durable data-file length the record vouches
//! for; `last_crc` is the CRC-32 of the most recently appended page frame
//! (or of the header when `pages_done == 0`); `record_crc` covers the 24
//! bytes before it. Records are append-only; a torn tail is tolerated by
//! taking the longest prefix of CRC-valid records.

use std::fs::{File, OpenOptions};
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};

use crate::util::crc32;

/// Leading bytes of every journal record (non-ASCII, like the page and
/// index magics, so text or zero runs cannot alias a record start).
pub const JOURNAL_MAGIC: [u8; 4] = [0xB4, 0x4A, 0x52, 0x1A]; // ´JR␚

/// Serialized size of one journal record.
pub const JOURNAL_RECORD_LEN: usize = 28;

/// One durable progress commit: the state of the data file after a page
/// (or the header, for `pages_done == 0`) was synced.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct JournalRecord {
    /// Pages fully durable on the data medium.
    pub pages_done: u32,
    /// Images those pages code.
    pub images_done: u32,
    /// Durable data-file length in bytes.
    pub bytes_written: u64,
    /// CRC-32 of the last appended page frame (header CRC-32 when no
    /// page has been written yet).
    pub last_crc: u32,
}

impl JournalRecord {
    /// Serialize to the fixed on-disk layout.
    pub fn to_bytes(&self) -> [u8; JOURNAL_RECORD_LEN] {
        let mut out = [0u8; JOURNAL_RECORD_LEN];
        out[..4].copy_from_slice(&JOURNAL_MAGIC);
        out[4..8].copy_from_slice(&self.pages_done.to_le_bytes());
        out[8..12].copy_from_slice(&self.images_done.to_le_bytes());
        out[12..20].copy_from_slice(&self.bytes_written.to_le_bytes());
        out[20..24].copy_from_slice(&self.last_crc.to_le_bytes());
        let crc = crc32::hash(&out[..24]);
        out[24..].copy_from_slice(&crc.to_le_bytes());
        out
    }

    /// Parse one record; `None` on short input, bad magic, or CRC
    /// mismatch (a torn or corrupted record).
    pub fn from_bytes(b: &[u8]) -> Option<Self> {
        if b.len() < JOURNAL_RECORD_LEN || b[..4] != JOURNAL_MAGIC {
            return None;
        }
        let stored = u32::from_le_bytes(b[24..28].try_into().unwrap());
        if crc32::hash(&b[..24]) != stored {
            return None;
        }
        Some(Self {
            pages_done: u32::from_le_bytes(b[4..8].try_into().unwrap()),
            images_done: u32::from_le_bytes(b[8..12].try_into().unwrap()),
            bytes_written: u64::from_le_bytes(b[12..20].try_into().unwrap()),
            last_crc: u32::from_le_bytes(b[20..24].try_into().unwrap()),
        })
    }
}

/// Longest valid prefix of an append-only journal: returns the byte
/// length of the intact records and the last one. A torn or corrupted
/// tail (partial final record after a cut) is simply not counted.
pub fn journal_prefix(journal: &[u8]) -> (usize, Option<JournalRecord>) {
    let mut at = 0usize;
    let mut last = None;
    while let Some(rec) = JournalRecord::from_bytes(&journal[at..]) {
        last = Some(rec);
        at += JOURNAL_RECORD_LEN;
    }
    (at, last)
}

/// Durable append-only byte sink with truncation — the storage target a
/// streaming writer commits pages and journal records to. `sync` must
/// make every previously appended byte durable before it returns; the
/// in-memory test media treat it as a no-op.
pub trait StreamMedium {
    /// Append `bytes` at the current end.
    fn append(&mut self, bytes: &[u8]) -> std::io::Result<()>;
    /// Make all appended bytes durable (fsync for file-backed media).
    fn sync(&mut self) -> std::io::Result<()>;
    /// Discard everything past `len` bytes (torn-tail removal on resume).
    fn truncate(&mut self, len: u64) -> std::io::Result<()>;
    /// Current length in bytes.
    fn len(&self) -> u64;
    /// True when no byte has been written (or all were truncated away).
    fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// File-backed medium: appends via a writer positioned at the end,
/// `sync` is `File::sync_data`, truncation is `File::set_len`.
#[derive(Debug)]
pub struct FileMedium {
    file: File,
    path: PathBuf,
    len: u64,
}

impl FileMedium {
    /// Create (or truncate) the file at `path`.
    pub fn create(path: &Path) -> std::io::Result<Self> {
        let file = OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .truncate(true)
            .open(path)?;
        Ok(Self {
            file,
            path: path.to_path_buf(),
            len: 0,
        })
    }

    /// Open an existing (or new) file for resumed appends; the caller is
    /// expected to `truncate` to the validated length before appending.
    pub fn open(path: &Path) -> std::io::Result<Self> {
        let file = OpenOptions::new().read(true).write(true).create(true).open(path)?;
        let len = file.metadata()?.len();
        Ok(Self {
            file,
            path: path.to_path_buf(),
            len,
        })
    }

    /// The path this medium writes to.
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Consume the medium and delete its file (journal finalization).
    pub fn remove(self) -> std::io::Result<()> {
        drop(self.file);
        std::fs::remove_file(&self.path)
    }

    /// Read the entire current contents (resume-time validation).
    pub fn read_all(&mut self) -> std::io::Result<Vec<u8>> {
        self.file.rewind()?;
        let mut buf = Vec::with_capacity(self.len as usize);
        self.file.read_to_end(&mut buf)?;
        self.file.seek(SeekFrom::End(0))?;
        Ok(buf)
    }
}

impl StreamMedium for FileMedium {
    fn append(&mut self, bytes: &[u8]) -> std::io::Result<()> {
        self.file.seek(SeekFrom::Start(self.len))?;
        self.file.write_all(bytes)?;
        self.len += bytes.len() as u64;
        Ok(())
    }

    fn sync(&mut self) -> std::io::Result<()> {
        self.file.sync_data()
    }

    fn truncate(&mut self, len: u64) -> std::io::Result<()> {
        self.file.set_len(len)?;
        self.file.seek(SeekFrom::Start(len))?;
        self.len = len;
        Ok(())
    }

    fn len(&self) -> u64 {
        self.len
    }
}

/// In-memory medium for tests and for building wire payloads; `sync` is
/// a no-op (a `Vec` is as durable as it gets).
#[derive(Debug, Default, Clone)]
pub struct VecMedium {
    pub buf: Vec<u8>,
}

impl VecMedium {
    pub fn new() -> Self {
        Self::default()
    }

    /// Start from existing bytes (resume over a recovered prefix).
    pub fn from_bytes(buf: Vec<u8>) -> Self {
        Self { buf }
    }
}

impl StreamMedium for VecMedium {
    fn append(&mut self, bytes: &[u8]) -> std::io::Result<()> {
        self.buf.extend_from_slice(bytes);
        Ok(())
    }

    fn sync(&mut self) -> std::io::Result<()> {
        Ok(())
    }

    fn truncate(&mut self, len: u64) -> std::io::Result<()> {
        self.buf.truncate(len as usize);
        Ok(())
    }

    fn len(&self) -> u64 {
        self.buf.len() as u64
    }
}

/// Sidecar journal path for a streamed data file: `<path>.journal`.
pub fn journal_path(path: &Path) -> PathBuf {
    let mut os = path.as_os_str().to_os_string();
    os.push(".journal");
    PathBuf::from(os)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(pages: u32) -> JournalRecord {
        JournalRecord {
            pages_done: pages,
            images_done: pages * 3,
            bytes_written: 100 + pages as u64 * 57,
            last_crc: 0xDEAD_0000 | pages,
        }
    }

    #[test]
    fn record_roundtrip() {
        let r = rec(5);
        let b = r.to_bytes();
        assert_eq!(b.len(), JOURNAL_RECORD_LEN);
        assert_eq!(JournalRecord::from_bytes(&b), Some(r));
    }

    #[test]
    fn every_flipped_bit_is_rejected() {
        let b = rec(2).to_bytes();
        for byte in 0..b.len() {
            for bit in 0..8 {
                let mut bad = b;
                bad[byte] ^= 1 << bit;
                assert_eq!(
                    JournalRecord::from_bytes(&bad),
                    None,
                    "flip at byte {byte} bit {bit} went undetected"
                );
            }
        }
    }

    #[test]
    fn journal_prefix_tolerates_torn_tail() {
        let mut j = Vec::new();
        for p in 0..4 {
            j.extend_from_slice(&rec(p).to_bytes());
        }
        // Cut at every byte: the prefix is always the intact records.
        for cut in 0..=j.len() {
            let (keep, last) = journal_prefix(&j[..cut]);
            let whole = cut / JOURNAL_RECORD_LEN;
            assert_eq!(keep, whole * JOURNAL_RECORD_LEN, "cut {cut}");
            assert_eq!(last, whole.checked_sub(1).map(|p| rec(p as u32)), "cut {cut}");
        }
    }

    #[test]
    fn journal_prefix_stops_at_corruption() {
        let mut j = Vec::new();
        for p in 0..3 {
            j.extend_from_slice(&rec(p).to_bytes());
        }
        j[JOURNAL_RECORD_LEN + 5] ^= 0xFF; // corrupt record 1
        let (keep, last) = journal_prefix(&j);
        assert_eq!(keep, JOURNAL_RECORD_LEN);
        assert_eq!(last, Some(rec(0)));
    }

    #[test]
    fn vec_medium_append_truncate() {
        let mut m = VecMedium::new();
        m.append(b"hello").unwrap();
        m.append(b" world").unwrap();
        assert_eq!(m.len(), 11);
        m.truncate(5).unwrap();
        assert_eq!(m.buf, b"hello");
        m.sync().unwrap();
    }

    #[test]
    fn journal_path_appends_suffix() {
        assert_eq!(
            journal_path(Path::new("/tmp/x.bbc4")),
            PathBuf::from("/tmp/x.bbc4.journal")
        );
    }
}
