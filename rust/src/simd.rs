//! Runtime-dispatched SIMD kernel selection and the shared vectorized
//! f64 helpers (ISSUE 5).
//!
//! Every explicit SIMD path in this crate is **bit-identical** to its
//! scalar twin — that is the ground rule, not an aspiration. BB-ANS
//! requires the decoder to reproduce the encoder's quantized
//! distributions exactly, and streams move between machines, so a kernel
//! variant may never change a single coded bit. The two disciplines that
//! make this possible:
//!
//! * **Vectorize across independent outputs, never across a reduction.**
//!   The GEMM microkernels ([`crate::model::tensor`]) spread the `NR`
//!   output-column lanes over one vector register and keep each element's
//!   accumulation order (bias, then `k` ascending) untouched; the
//!   beta-binomial batch constructor runs four *pixels'* recurrences in
//!   four lanes, each lane executing exactly the scalar op sequence.
//!   Lane-wise IEEE-754 mul/add/div are identical to their scalar
//!   counterparts, so this is exact. FMA is **never** used — it fuses the
//!   rounding step that the scalar code performs twice.
//! * **Emulate libm exactly or stay scalar.** `f64::round` (half away
//!   from zero) is reproduced for the non-negative quantizer domain as
//!   `floor(x) + (x − floor(x) ≥ ½)`, which is exact because
//!   `x − floor(x)` is always exact for `x ≥ 0` (Sterbenz for `x ≥ 1`,
//!   trivial below). Transcendentals (`exp`, `ln_1p` in the GEMM
//!   epilogues) stay scalar per lane — no vector approximation matches
//!   libm bit-for-bit.
//!
//! Dispatch is resolved once per process: AVX2 on `x86_64` when the CPU
//! reports it, NEON on `aarch64` (baseline there), scalar otherwise. The
//! `BBANS_FORCE_SCALAR` environment variable (any value except `0` or
//! empty) pins the scalar path — the debugging escape hatch documented in
//! the README — and [`force`] lets tests flip variants in-process to pin
//! the bit-identity contract.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;

/// A compute-kernel variant. All variants are bit-identical; the choice
/// affects throughput only, which is why it is deliberately **not** part
/// of any container's `backend_id` (see `Backend::kernel_id`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Kernel {
    /// Portable scalar code (the reference semantics).
    Scalar,
    /// 8-lane f32 / 4-lane f64 AVX2 paths (`x86_64`, runtime-detected).
    Avx2,
    /// 4-lane f32 NEON paths (`aarch64`, baseline feature there).
    Neon,
}

impl Kernel {
    /// Stable lowercase name, used in `kernel_id` strings and bench
    /// annotations.
    pub fn name(self) -> &'static str {
        match self {
            Kernel::Scalar => "scalar",
            Kernel::Avx2 => "avx2",
            Kernel::Neon => "neon",
        }
    }
}

/// Test/debug override: 0 = none, else `Kernel` discriminant + 1.
static OVERRIDE: AtomicU8 = AtomicU8::new(0);
static DETECTED: OnceLock<Kernel> = OnceLock::new();

fn detect() -> Kernel {
    // Escape hatch first: BBANS_FORCE_SCALAR pins the scalar path for
    // debugging and for CI's forced-scalar leg (unset, empty or "0"
    // leaves dispatch alone).
    match std::env::var("BBANS_FORCE_SCALAR") {
        Ok(v) if !v.is_empty() && v != "0" => Kernel::Scalar,
        _ => detect_arch(),
    }
}

#[cfg(target_arch = "x86_64")]
fn detect_arch() -> Kernel {
    if std::arch::is_x86_feature_detected!("avx2") {
        Kernel::Avx2
    } else {
        Kernel::Scalar
    }
}

#[cfg(target_arch = "aarch64")]
fn detect_arch() -> Kernel {
    Kernel::Neon
}

#[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
fn detect_arch() -> Kernel {
    Kernel::Scalar
}

/// The kernel variant every dispatched hot path uses right now.
#[inline]
pub fn active() -> Kernel {
    match OVERRIDE.load(Ordering::Relaxed) {
        1 => Kernel::Scalar,
        2 => Kernel::Avx2,
        3 => Kernel::Neon,
        _ => *DETECTED.get_or_init(detect),
    }
}

/// Name of the active kernel (diagnostics, bench annotations,
/// `kernel_id`).
pub fn kernel_name() -> &'static str {
    active().name()
}

/// Every variant this process can actually execute (always includes
/// [`Kernel::Scalar`]). Tests iterate this to pin cross-variant
/// bit-identity.
pub fn available() -> Vec<Kernel> {
    let mut out = vec![Kernel::Scalar];
    #[cfg(target_arch = "x86_64")]
    if std::arch::is_x86_feature_detected!("avx2") {
        out.push(Kernel::Avx2);
    }
    #[cfg(target_arch = "aarch64")]
    out.push(Kernel::Neon);
    out
}

/// Pin dispatch to one variant (`None` restores runtime detection).
/// Panics if `k` is not in [`available`] — forcing an unsupported variant
/// would execute illegal instructions. Intended for tests and benches;
/// the change is process-global.
pub fn force(k: Option<Kernel>) {
    if let Some(k) = k {
        assert!(
            available().contains(&k),
            "kernel {k:?} is not available on this CPU"
        );
    }
    let v = match k {
        None => 0,
        Some(Kernel::Scalar) => 1,
        Some(Kernel::Avx2) => 2,
        Some(Kernel::Neon) => 3,
    };
    OVERRIDE.store(v, Ordering::Relaxed);
}

// ------------------------------------------------------- f64 helpers

/// Widen an f32 PMF row to f64, mapping every non-finite or non-positive
/// entry to `0.0` — exactly `if p.is_finite() && p > 0.0 { p } else
/// { 0.0 }` on the widened value, vectorized. `dst` is cleared first and
/// every element is written exactly once (no zero-fill pass: this sits
/// on the per-pixel table hot path).
// The AVX2 arm initializes the spare capacity through
// `widen_sanitize_f32_avx2` before `set_len`; clippy cannot see through
// the call.
#[allow(clippy::uninit_vec)]
pub fn widen_sanitize_f32(src: &[f32], dst: &mut Vec<f64>) {
    dst.clear();
    #[cfg(target_arch = "x86_64")]
    if active() == Kernel::Avx2 {
        dst.reserve(src.len());
        // SAFETY: AVX2 availability checked by dispatch; the body writes
        // all `src.len()` elements of the spare capacity before set_len.
        unsafe {
            widen_sanitize_f32_avx2(src, dst.spare_capacity_mut().as_mut_ptr() as *mut f64);
            dst.set_len(src.len());
        }
        return;
    }
    dst.extend(src.iter().map(|&s| {
        let p = s as f64;
        if p.is_finite() && p > 0.0 {
            p
        } else {
            0.0
        }
    }));
}

/// Scalar reference used by the cross-variant tests.
#[cfg(test)]
fn widen_sanitize_f32_scalar(src: &[f32], dst: &mut [f64]) {
    for (d, &s) in dst.iter_mut().zip(src.iter()) {
        let p = s as f64;
        *d = if p.is_finite() && p > 0.0 { p } else { 0.0 };
    }
}

/// Writes exactly `src.len()` f64s starting at `out` (which must be
/// valid for that many writes; may be uninitialized).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn widen_sanitize_f32_avx2(src: &[f32], out: *mut f64) {
    use core::arch::x86_64::*;
    let n = src.len();
    let mut i = 0;
    let zero = _mm256_setzero_pd();
    let inf = _mm256_set1_pd(f64::INFINITY);
    while i + 4 <= n {
        let v = _mm256_cvtps_pd(_mm_loadu_ps(src.as_ptr().add(i)));
        // valid ⟺ 0 < v < +∞ (NaN fails both ordered compares).
        let gt0 = _mm256_cmp_pd::<_CMP_GT_OQ>(v, zero);
        let fin = _mm256_cmp_pd::<_CMP_LT_OQ>(v, inf);
        let keep = _mm256_and_pd(gt0, fin);
        _mm256_storeu_pd(out.add(i), _mm256_and_pd(keep, v));
        i += 4;
    }
    while i < n {
        let p = *src.get_unchecked(i) as f64;
        out.add(i)
            .write(if p.is_finite() && p > 0.0 { p } else { 0.0 });
        i += 1;
    }
}

/// In place, `x[i] ← round_half_away(x[i] · scale)` for the non-negative
/// quantizer domain — bit-identical to `(x[i] * scale).round()` there
/// (see the module docs for the floor-based emulation argument; pinned
/// by `round_emulation_matches_f64_round` below). This is the vectorized
/// core of `QuantizedCdf` construction.
pub fn scaled_round_half_away(xs: &mut [f64], scale: f64) {
    #[cfg(target_arch = "x86_64")]
    if active() == Kernel::Avx2 {
        // SAFETY: AVX2 availability checked by dispatch.
        unsafe { scaled_round_half_away_avx2(xs, scale) };
        return;
    }
    scaled_round_half_away_scalar(xs, scale);
}

/// The one formula every variant uses, so scalar and SIMD machines agree
/// even on inputs outside the sanitized domain.
#[inline(always)]
fn round_half_away_nonneg(v: f64) -> f64 {
    let f = v.floor();
    // `v - f` is exact for v ≥ 0; a NaN fraction (v = ±∞/NaN) fails the
    // comparison, matching `f64::round`'s identity on those inputs.
    f + f64::from(u8::from(v - f >= 0.5))
}

fn scaled_round_half_away_scalar(xs: &mut [f64], scale: f64) {
    for x in xs {
        *x = round_half_away_nonneg(*x * scale);
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn scaled_round_half_away_avx2(xs: &mut [f64], scale: f64) {
    use core::arch::x86_64::*;
    let n = xs.len();
    let s = _mm256_set1_pd(scale);
    let half = _mm256_set1_pd(0.5);
    let one = _mm256_set1_pd(1.0);
    let mut i = 0;
    while i + 4 <= n {
        let v = _mm256_mul_pd(_mm256_loadu_pd(xs.as_ptr().add(i)), s);
        let f = _mm256_floor_pd(v);
        let frac = _mm256_sub_pd(v, f);
        let up = _mm256_and_pd(_mm256_cmp_pd::<_CMP_GE_OQ>(frac, half), one);
        _mm256_storeu_pd(xs.as_mut_ptr().add(i), _mm256_add_pd(f, up));
        i += 4;
    }
    scaled_round_half_away_scalar(&mut xs[i..], scale);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use std::sync::{Mutex, MutexGuard};

    /// Tests that flip the process-global override serialize on this lock
    /// so the harness's test threads cannot observe each other's forcing.
    fn forced(k: Kernel) -> MutexGuard<'static, ()> {
        static LOCK: Mutex<()> = Mutex::new(());
        let guard = LOCK.lock().unwrap_or_else(|p| p.into_inner());
        force(Some(k));
        guard
    }

    #[test]
    fn dispatch_reports_a_real_variant() {
        let avail = available();
        assert!(avail.contains(&Kernel::Scalar));
        assert!(avail.contains(&active()), "active kernel must be available");
        assert!(!kernel_name().is_empty());
    }

    #[test]
    fn force_round_trips_and_rejects_unavailable() {
        let before = *DETECTED.get_or_init(detect);
        let guard = forced(Kernel::Scalar);
        assert_eq!(active(), Kernel::Scalar);
        force(None);
        assert_eq!(active(), before);
        #[cfg(target_arch = "x86_64")]
        {
            let r = std::panic::catch_unwind(|| force(Some(Kernel::Neon)));
            assert!(r.is_err(), "forcing NEON on x86_64 must panic");
            force(None);
        }
        drop(guard);
    }

    #[test]
    fn round_emulation_matches_f64_round() {
        // The floor-based emulation must equal f64::round on the whole
        // non-negative domain, including exact .5 ties (away from zero)
        // and the largest double below 0.5 (where `v + 0.5` would round
        // to 1.0 and a naive trunc(v + 0.5) would be wrong).
        let mut rng = Rng::new(0x51D);
        for _ in 0..200_000 {
            let e = rng.below(56) as i32 - 3;
            let v = rng.f64() * (2.0f64).powi(e);
            assert_eq!(
                round_half_away_nonneg(v).to_bits(),
                v.round().to_bits(),
                "v={v:e}"
            );
        }
        for t in 0..1000u32 {
            let v = t as f64 + 0.5;
            assert_eq!(round_half_away_nonneg(v), v.round());
        }
        let edge = 0.49999999999999994f64; // largest f64 < 0.5
        assert_eq!(round_half_away_nonneg(edge), 0.0);
        assert_eq!(round_half_away_nonneg(0.0), 0.0);
        assert!(round_half_away_nonneg(f64::INFINITY).is_infinite());
    }

    #[test]
    fn widen_sanitize_matches_scalar_on_every_variant() {
        let mut rng = Rng::new(0xA11);
        for len in [0usize, 1, 3, 4, 5, 17, 256, 1023] {
            let src: Vec<f32> = (0..len)
                .map(|i| match i % 7 {
                    0 => 0.0,
                    1 => -1.5,
                    2 => f32::NAN,
                    3 => f32::INFINITY,
                    4 => f32::NEG_INFINITY,
                    5 => f32::MIN_POSITIVE / 2.0, // subnormal
                    _ => (rng.f64() * 10.0) as f32,
                })
                .collect();
            let mut want = vec![0.0f64; len];
            widen_sanitize_f32_scalar(&src, &mut want);
            for &k in &available() {
                let guard = forced(k);
                let mut got = Vec::new();
                widen_sanitize_f32(&src, &mut got);
                force(None);
                drop(guard);
                assert_eq!(got.len(), want.len());
                for (i, (g, w)) in got.iter().zip(want.iter()).enumerate() {
                    assert_eq!(g.to_bits(), w.to_bits(), "{k:?} len={len} i={i}");
                }
            }
        }
    }

    #[test]
    fn scaled_round_matches_scalar_on_every_variant() {
        let mut rng = Rng::new(0xB22);
        for len in [0usize, 1, 4, 7, 255, 256] {
            let base: Vec<f64> = (0..len)
                .map(|i| {
                    if i % 11 == 0 {
                        i as f64 / 2.0 // exact .5 ties after scale = 1.0
                    } else {
                        rng.f64() * 1e6
                    }
                })
                .collect();
            for scale in [1.0f64, 0.37, 65519.0, 1e-12] {
                let mut want = base.clone();
                scaled_round_half_away_scalar(&mut want, scale);
                for (w, &b) in want.iter().zip(base.iter()) {
                    assert_eq!(w.to_bits(), (b * scale).round().to_bits());
                }
                for &k in &available() {
                    let guard = forced(k);
                    let mut got = base.clone();
                    scaled_round_half_away(&mut got, scale);
                    force(None);
                    drop(guard);
                    for (i, (g, w)) in got.iter().zip(want.iter()).enumerate() {
                        assert_eq!(g.to_bits(), w.to_bits(), "{k:?} len={len} i={i}");
                    }
                }
            }
        }
    }
}
