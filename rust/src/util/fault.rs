//! Deterministic fault injection for container robustness testing.
//!
//! Models the storage failures a container can meet in the wild — flipped
//! bits, files truncated mid-write, torn writes that leave a stale tail,
//! zeroed sectors — as reproducible [`Fault`] values. Campaigns are
//! seeded, so a failing case prints a description that replays exactly.

use crate::util::rng::Rng;

/// One storage fault, applicable to any byte buffer.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Fault {
    /// XOR one bit.
    BitFlip { offset: usize, bit: u8 },
    /// Drop everything past `len` (interrupted write / short read).
    Truncate { len: usize },
    /// Zero a byte range (a blanked sector).
    ZeroFill { start: usize, len: usize },
    /// Torn write: bytes from `at` on are replaced with pseudo-random
    /// garbage derived from `stale_seed` (the old sector contents), same
    /// total length.
    Torn { at: usize, stale_seed: u64 },
}

impl Fault {
    /// Apply the fault to a copy of `bytes`. Out-of-range positions clamp
    /// rather than panic, so campaigns can be generated independently of
    /// the exact buffer size.
    pub fn apply(&self, bytes: &[u8]) -> Vec<u8> {
        let mut out = bytes.to_vec();
        match *self {
            Fault::BitFlip { offset, bit } => {
                if let Some(b) = out.get_mut(offset) {
                    *b ^= 1 << (bit & 7);
                }
            }
            Fault::Truncate { len } => out.truncate(len),
            Fault::ZeroFill { start, len } => {
                let s = start.min(out.len());
                let e = (start + len).min(out.len());
                out[s..e].fill(0);
            }
            Fault::Torn { at, stale_seed } => {
                let s = at.min(out.len());
                let mut stale = Rng::new(stale_seed | 1);
                for b in &mut out[s..] {
                    *b = stale.next_u64() as u8;
                }
            }
        }
        out
    }

    /// A replayable one-line description for assertion messages.
    pub fn describe(&self) -> String {
        match *self {
            Fault::BitFlip { offset, bit } => format!("bit flip at byte {offset} bit {bit}"),
            Fault::Truncate { len } => format!("truncation to {len} bytes"),
            Fault::ZeroFill { start, len } => format!("zero fill of {len} bytes at {start}"),
            Fault::Torn { at, stale_seed } => {
                format!("torn write at {at} (stale seed {stale_seed:#x})")
            }
        }
    }
}

/// A seeded mixed campaign over a `len`-byte buffer: `n` faults drawn from
/// all four kinds with uniformly random positions. Deterministic in
/// `(seed, len, n)`.
pub fn campaign(seed: u64, len: usize, n: usize) -> Vec<Fault> {
    let mut rng = Rng::new(seed | 1);
    let mut out = Vec::with_capacity(n);
    let pos = |rng: &mut Rng| rng.below(len.max(1) as u64) as usize;
    for _ in 0..n {
        out.push(match rng.below(4) {
            0 => Fault::BitFlip {
                offset: pos(&mut rng),
                bit: rng.below(8) as u8,
            },
            1 => Fault::Truncate { len: pos(&mut rng) },
            2 => Fault::ZeroFill {
                start: pos(&mut rng),
                len: 1 + pos(&mut rng) / 4,
            },
            _ => Fault::Torn {
                at: pos(&mut rng),
                stale_seed: rng.next_u64(),
            },
        });
    }
    out
}

/// Truncations bracketing every boundary in `boundaries` (each ±1 and
/// exact), deduplicated and clamped to `len` — the frame-edge sweep that
/// catches off-by-one parsing.
pub fn boundary_truncations(boundaries: &[usize], len: usize) -> Vec<Fault> {
    let mut cuts: Vec<usize> = boundaries
        .iter()
        .flat_map(|&b| [b.saturating_sub(1), b, b + 1])
        .map(|c| c.min(len))
        .collect();
    cuts.sort_unstable();
    cuts.dedup();
    cuts.into_iter().map(|len| Fault::Truncate { len }).collect()
}

/// One bit flip in every byte position stride-`stride` across the buffer
/// (bit index varies deterministically) — a cheap full-coverage sweep.
pub fn bitflip_sweep(len: usize, stride: usize) -> Vec<Fault> {
    (0..len)
        .step_by(stride.max(1))
        .map(|offset| Fault::BitFlip {
            offset,
            bit: (offset % 8) as u8,
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn faults_are_deterministic_and_clamped() {
        let data: Vec<u8> = (0..64u8).collect();
        let c1 = campaign(7, data.len(), 20);
        let c2 = campaign(7, data.len(), 20);
        assert_eq!(c1, c2, "campaigns must replay exactly");
        for f in &c1 {
            let mutated = f.apply(&data);
            assert_eq!(mutated, f.apply(&data), "{} not deterministic", f.describe());
            assert!(mutated.len() <= data.len());
        }
        // Out-of-range positions are no-ops or clamps, never panics.
        let far = Fault::BitFlip {
            offset: 10_000,
            bit: 3,
        };
        assert_eq!(far.apply(&data), data);
        let zf = Fault::ZeroFill {
            start: 60,
            len: 100,
        };
        assert_eq!(zf.apply(&data)[60..], [0, 0, 0, 0]);
    }

    #[test]
    fn boundary_truncations_bracket_each_edge() {
        let cuts = boundary_truncations(&[0, 10, 64], 64);
        let lens: Vec<usize> = cuts
            .iter()
            .map(|f| match f {
                Fault::Truncate { len } => *len,
                other => panic!("unexpected {other:?}"),
            })
            .collect();
        assert_eq!(lens, vec![0, 1, 9, 10, 11, 63, 64]);
    }

    #[test]
    fn torn_write_keeps_prefix_and_length() {
        let data = vec![0xAB; 32];
        let torn = Fault::Torn {
            at: 8,
            stale_seed: 99,
        };
        let out = torn.apply(&data);
        assert_eq!(out.len(), 32);
        assert_eq!(out[..8], data[..8]);
        assert_ne!(out[8..], data[8..]);
    }
}
