//! Deterministic fault injection for robustness testing.
//!
//! Two layers, one seeding discipline:
//!
//! - **Bytes at rest** — the storage failures a container can meet in
//!   the wild (flipped bits, files truncated mid-write, torn writes that
//!   leave a stale tail, zeroed sectors) as reproducible [`Fault`]
//!   values.
//! - **Live dispatches** — the serving failures a request can cause,
//!   injected by wrapping any [`Backend`] in a [`FaultyBackend`]: a
//!   panic mid-dispatch, an `Err` return, a latency spike. Used by the
//!   chaos campaigns (`tests/chaos.rs`) to prove the coordinator
//!   contains every one of them.
//!
//! Campaigns are seeded, so a failing case prints a description that
//! replays exactly.

use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{bail, Result};

use crate::model::tensor::Matrix;
use crate::model::{Backend, ModelMeta, PixelParams, PosteriorBatch};
use crate::util::rng::Rng;

/// One storage fault, applicable to any byte buffer.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Fault {
    /// XOR one bit.
    BitFlip { offset: usize, bit: u8 },
    /// Drop everything past `len` (interrupted write / short read).
    Truncate { len: usize },
    /// Zero a byte range (a blanked sector).
    ZeroFill { start: usize, len: usize },
    /// Torn write: bytes from `at` on are replaced with pseudo-random
    /// garbage derived from `stale_seed` (the old sector contents), same
    /// total length.
    Torn { at: usize, stale_seed: u64 },
}

impl Fault {
    /// Apply the fault to a copy of `bytes`. Out-of-range positions clamp
    /// rather than panic, so campaigns can be generated independently of
    /// the exact buffer size.
    pub fn apply(&self, bytes: &[u8]) -> Vec<u8> {
        let mut out = bytes.to_vec();
        match *self {
            Fault::BitFlip { offset, bit } => {
                if let Some(b) = out.get_mut(offset) {
                    *b ^= 1 << (bit & 7);
                }
            }
            Fault::Truncate { len } => out.truncate(len),
            Fault::ZeroFill { start, len } => {
                let s = start.min(out.len());
                let e = (start + len).min(out.len());
                out[s..e].fill(0);
            }
            Fault::Torn { at, stale_seed } => {
                let s = at.min(out.len());
                let mut stale = Rng::new(stale_seed | 1);
                for b in &mut out[s..] {
                    *b = stale.next_u64() as u8;
                }
            }
        }
        out
    }

    /// A replayable one-line description for assertion messages.
    pub fn describe(&self) -> String {
        match *self {
            Fault::BitFlip { offset, bit } => format!("bit flip at byte {offset} bit {bit}"),
            Fault::Truncate { len } => format!("truncation to {len} bytes"),
            Fault::ZeroFill { start, len } => format!("zero fill of {len} bytes at {start}"),
            Fault::Torn { at, stale_seed } => {
                format!("torn write at {at} (stale seed {stale_seed:#x})")
            }
        }
    }
}

/// A seeded mixed campaign over a `len`-byte buffer: `n` faults drawn from
/// all four kinds with uniformly random positions. Deterministic in
/// `(seed, len, n)`.
pub fn campaign(seed: u64, len: usize, n: usize) -> Vec<Fault> {
    let mut rng = Rng::new(seed | 1);
    let mut out = Vec::with_capacity(n);
    let pos = |rng: &mut Rng| rng.below(len.max(1) as u64) as usize;
    for _ in 0..n {
        out.push(match rng.below(4) {
            0 => Fault::BitFlip {
                offset: pos(&mut rng),
                bit: rng.below(8) as u8,
            },
            1 => Fault::Truncate { len: pos(&mut rng) },
            2 => Fault::ZeroFill {
                start: pos(&mut rng),
                len: 1 + pos(&mut rng) / 4,
            },
            _ => Fault::Torn {
                at: pos(&mut rng),
                stale_seed: rng.next_u64(),
            },
        });
    }
    out
}

/// Truncations bracketing every boundary in `boundaries` (each ±1 and
/// exact), deduplicated and clamped to `len` — the frame-edge sweep that
/// catches off-by-one parsing.
pub fn boundary_truncations(boundaries: &[usize], len: usize) -> Vec<Fault> {
    let mut cuts: Vec<usize> = boundaries
        .iter()
        .flat_map(|&b| [b.saturating_sub(1), b, b + 1])
        .map(|c| c.min(len))
        .collect();
    cuts.sort_unstable();
    cuts.dedup();
    cuts.into_iter().map(|len| Fault::Truncate { len }).collect()
}

/// Power-cut campaign over a streamed write sequence: every boundary cut
/// (±1 and exact, the [`boundary_truncations`] sweep) plus `per_gap`
/// seeded mid-page cuts strictly inside each gap between consecutive
/// boundaries — the two places a real power cut lands: right at a page
/// commit, or partway through one. Deterministic in
/// `(seed, boundaries, len, per_gap)`.
pub fn powercut_campaign(
    seed: u64,
    boundaries: &[usize],
    len: usize,
    per_gap: usize,
) -> Vec<Fault> {
    let mut edges: Vec<usize> = boundaries.iter().map(|&b| b.min(len)).collect();
    edges.push(0);
    edges.push(len);
    edges.sort_unstable();
    edges.dedup();
    let mut rng = Rng::new(seed | 1);
    let mut cuts: Vec<usize> = boundary_truncations(&edges, len)
        .into_iter()
        .map(|f| match f {
            Fault::Truncate { len } => len,
            other => unreachable!("boundary_truncations yields truncations, got {other:?}"),
        })
        .collect();
    for gap in edges.windows(2) {
        let (lo, hi) = (gap[0], gap[1]);
        if hi - lo > 2 {
            for _ in 0..per_gap {
                // Strictly interior: a mid-page cut, never the commit edge.
                cuts.push(lo + 1 + rng.below((hi - lo - 2) as u64 + 1) as usize);
            }
        }
    }
    cuts.sort_unstable();
    cuts.dedup();
    cuts.into_iter().map(|len| Fault::Truncate { len }).collect()
}

/// One bit flip in every byte position stride-`stride` across the buffer
/// (bit index varies deterministically) — a cheap full-coverage sweep.
pub fn bitflip_sweep(len: usize, stride: usize) -> Vec<Fault> {
    (0..len)
        .step_by(stride.max(1))
        .map(|offset| Fault::BitFlip {
            offset,
            bit: (offset % 8) as u8,
        })
        .collect()
}

/// One fault to inject into a live NN dispatch (an `encode_batch` or
/// `decode_batch` call) of a [`FaultyBackend`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum DispatchFault {
    /// Panic mid-dispatch — a poisoned weight blob, an out-of-bounds
    /// kernel. The coordinator's supervisor must contain it.
    Panic,
    /// Return `Err` — a failed device, a rejected shape. An ordinary
    /// error path; no unwinding.
    Error,
    /// Answer correctly, but only after sleeping — a contended device or
    /// an allocator stall. Exercises TTL shedding and drain deadlines.
    Delay(Duration),
}

impl DispatchFault {
    /// A replayable one-line description for assertion messages.
    pub fn describe(&self) -> String {
        match self {
            DispatchFault::Panic => "panic".to_string(),
            DispatchFault::Error => "error return".to_string(),
            DispatchFault::Delay(d) => format!("{}ms delay", d.as_millis()),
        }
    }
}

/// Faults keyed by 0-based dispatch index (`encode_batch` and
/// `decode_batch` share one counter, in call order). The same plan
/// against the same request schedule faults exactly the same calls.
#[derive(Debug, Clone, Default)]
pub struct FaultPlan {
    at: BTreeMap<u64, DispatchFault>,
}

impl FaultPlan {
    pub fn new() -> Self {
        Self::default()
    }

    /// Builder-style: add a fault at dispatch index `call`.
    pub fn fault_at(mut self, call: u64, fault: DispatchFault) -> Self {
        self.at.insert(call, fault);
        self
    }

    /// Seeded mixed schedule: roughly one in `every` of the first
    /// `calls` dispatches faults, kind drawn uniformly across
    /// panic/error/delay. Deterministic in `(seed, calls, every)`.
    pub fn campaign(seed: u64, calls: u64, every: u64) -> Self {
        let mut rng = Rng::new(seed | 1);
        let mut at = BTreeMap::new();
        for call in 0..calls {
            if rng.below(every.max(1)) == 0 {
                at.insert(
                    call,
                    match rng.below(3) {
                        0 => DispatchFault::Panic,
                        1 => DispatchFault::Error,
                        _ => DispatchFault::Delay(Duration::from_millis(1 + rng.below(20))),
                    },
                );
            }
        }
        Self { at }
    }

    pub fn get(&self, call: u64) -> Option<&DispatchFault> {
        self.at.get(&call)
    }

    pub fn len(&self) -> usize {
        self.at.len()
    }

    pub fn is_empty(&self) -> bool {
        self.at.is_empty()
    }
}

/// Shared view into a [`FaultyBackend`] that survives moving the backend
/// into a service factory: a test keeps the `Arc`, arms one-shot faults
/// at chosen moments, and reads the dispatch counter (e.g. to prove a
/// shed job never reached the NN).
#[derive(Debug, Default)]
pub struct FaultControl {
    calls: AtomicU64,
    armed: Mutex<VecDeque<DispatchFault>>,
}

impl FaultControl {
    /// Total dispatches seen so far (faulted or not).
    pub fn calls(&self) -> u64 {
        self.calls.load(Ordering::SeqCst)
    }

    /// Queue a one-shot fault for the next dispatch (FIFO when several
    /// are armed). Takes priority over the static plan.
    pub fn arm(&self, fault: DispatchFault) {
        self.armed
            .lock()
            .expect("fault arm lock poisoned")
            .push_back(fault);
    }

    /// Armed faults not yet consumed by a dispatch.
    pub fn armed_len(&self) -> usize {
        self.armed.lock().expect("fault arm lock poisoned").len()
    }

    fn take_armed(&self) -> Option<DispatchFault> {
        self.armed
            .lock()
            .expect("fault arm lock poisoned")
            .pop_front()
    }
}

/// A [`Backend`] wrapper that injects seeded, replayable faults into live
/// NN dispatches. Everything that affects container bytes — metadata,
/// `backend_id`, the un-faulted dispatch results — delegates to the
/// inner backend untouched, so requests that survive a chaos campaign
/// must produce bytes bit-identical to a fault-free run.
pub struct FaultyBackend<B> {
    inner: B,
    plan: FaultPlan,
    control: Arc<FaultControl>,
}

impl<B: Backend> FaultyBackend<B> {
    pub fn new(inner: B, plan: FaultPlan) -> Self {
        Self {
            inner,
            plan,
            control: Arc::new(FaultControl::default()),
        }
    }

    /// The shared control handle — clone it out before moving the
    /// backend into a service factory.
    pub fn control(&self) -> Arc<FaultControl> {
        self.control.clone()
    }

    fn inject(&self, what: &str) -> Result<()> {
        let call = self.control.calls.fetch_add(1, Ordering::SeqCst);
        let fault = self
            .control
            .take_armed()
            .or_else(|| self.plan.get(call).cloned());
        match fault {
            None => Ok(()),
            Some(DispatchFault::Panic) => {
                panic!("injected: {what} dispatch {call} hit a planned panic")
            }
            Some(DispatchFault::Error) => {
                bail!("injected: {what} dispatch {call} hit a planned error")
            }
            Some(DispatchFault::Delay(d)) => {
                std::thread::sleep(d);
                Ok(())
            }
        }
    }
}

impl<B: Backend> Backend for FaultyBackend<B> {
    fn meta(&self) -> &ModelMeta {
        self.inner.meta()
    }

    // Delegated, not wrapped: the wrapper must be invisible in container
    // bytes, or the chaos campaign's bit-identity assertion would compare
    // containers from two different nominal backends.
    fn backend_id(&self) -> String {
        self.inner.backend_id()
    }

    fn kernel_id(&self) -> String {
        self.inner.kernel_id()
    }

    fn posterior(&self, xs: &[&[f32]]) -> Result<Vec<(Vec<f32>, Vec<f32>)>> {
        self.inner.posterior(xs)
    }

    fn likelihood(&self, ys: &[&[f32]]) -> Result<Vec<PixelParams>> {
        self.inner.likelihood(ys)
    }

    fn encode_batch(&self, xs: &Matrix) -> Result<PosteriorBatch> {
        self.inject("encode_batch")?;
        self.inner.encode_batch(xs)
    }

    fn decode_batch(&self, ys: &Matrix) -> Result<Vec<PixelParams>> {
        self.inject("decode_batch")?;
        self.inner.decode_batch(ys)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn faults_are_deterministic_and_clamped() {
        let data: Vec<u8> = (0..64u8).collect();
        let c1 = campaign(7, data.len(), 20);
        let c2 = campaign(7, data.len(), 20);
        assert_eq!(c1, c2, "campaigns must replay exactly");
        for f in &c1 {
            let mutated = f.apply(&data);
            assert_eq!(mutated, f.apply(&data), "{} not deterministic", f.describe());
            assert!(mutated.len() <= data.len());
        }
        // Out-of-range positions are no-ops or clamps, never panics.
        let far = Fault::BitFlip {
            offset: 10_000,
            bit: 3,
        };
        assert_eq!(far.apply(&data), data);
        let zf = Fault::ZeroFill {
            start: 60,
            len: 100,
        };
        assert_eq!(zf.apply(&data)[60..], [0, 0, 0, 0]);
    }

    #[test]
    fn boundary_truncations_bracket_each_edge() {
        let cuts = boundary_truncations(&[0, 10, 64], 64);
        let lens: Vec<usize> = cuts
            .iter()
            .map(|f| match f {
                Fault::Truncate { len } => *len,
                other => panic!("unexpected {other:?}"),
            })
            .collect();
        assert_eq!(lens, vec![0, 1, 9, 10, 11, 63, 64]);
    }

    #[test]
    fn powercut_campaign_replays_and_covers_edges_and_interiors() {
        let bounds = [40, 100, 160];
        let a = powercut_campaign(3, &bounds, 200, 2);
        assert_eq!(a, powercut_campaign(3, &bounds, 200, 2), "must replay");
        let lens: Vec<usize> = a
            .iter()
            .map(|f| match f {
                Fault::Truncate { len } => *len,
                other => panic!("unexpected {other:?}"),
            })
            .collect();
        // Every commit edge is bracketed ±1 …
        for b in bounds {
            for c in [b - 1, b, b + 1] {
                assert!(lens.contains(&c), "missing boundary cut {c}");
            }
        }
        // … plus seeded cuts strictly inside the gaps (mid-page).
        let edge_only = boundary_truncations(&[0, 40, 100, 160, 200], 200).len();
        assert!(lens.len() > edge_only, "no mid-page cuts added: {lens:?}");
        // Sorted, deduplicated, clamped.
        assert!(lens.windows(2).all(|w| w[0] < w[1]));
        assert!(*lens.last().unwrap() <= 200);
    }

    #[test]
    fn torn_write_keeps_prefix_and_length() {
        let data = vec![0xAB; 32];
        let torn = Fault::Torn {
            at: 8,
            stale_seed: 99,
        };
        let out = torn.apply(&data);
        assert_eq!(out.len(), 32);
        assert_eq!(out[..8], data[..8]);
        assert_ne!(out[8..], data[8..]);
    }

    use crate::model::Likelihood;

    /// Minimal deterministic backend for exercising the wrapper.
    struct StubVae {
        meta: ModelMeta,
    }

    impl StubVae {
        fn new() -> Self {
            Self {
                meta: ModelMeta {
                    name: "stub".into(),
                    pixels: 4,
                    latent_dim: 2,
                    hidden: 3,
                    likelihood: Likelihood::Bernoulli,
                    test_elbo_bpd: 0.0,
                },
            }
        }
    }

    impl Backend for StubVae {
        fn meta(&self) -> &ModelMeta {
            &self.meta
        }

        fn backend_id(&self) -> String {
            "stub-v1".into()
        }

        fn posterior(&self, xs: &[&[f32]]) -> Result<Vec<(Vec<f32>, Vec<f32>)>> {
            Ok(xs.iter().map(|_| (vec![0.0; 2], vec![1.0; 2])).collect())
        }

        fn likelihood(&self, ys: &[&[f32]]) -> Result<Vec<PixelParams>> {
            Ok(ys
                .iter()
                .map(|_| PixelParams::Bernoulli(vec![0.5; 4]))
                .collect())
        }
    }

    #[test]
    fn dispatch_campaigns_replay_exactly() {
        let a = FaultPlan::campaign(11, 100, 5);
        let b = FaultPlan::campaign(11, 100, 5);
        for call in 0..100 {
            assert_eq!(a.get(call), b.get(call));
        }
        assert!(!a.is_empty(), "1-in-5 over 100 calls should fault at least once");
        assert!(a.len() < 100);
    }

    #[test]
    fn faulty_backend_injects_per_plan_and_stays_transparent() {
        let plan = FaultPlan::new()
            .fault_at(1, DispatchFault::Error)
            .fault_at(2, DispatchFault::Delay(Duration::from_millis(1)));
        let fb = FaultyBackend::new(StubVae::new(), plan);
        let ctl = fb.control();
        let xs = Matrix::new(1, 4, vec![0.0; 4]);
        // Call 0: clean, bit-identical to the inner backend's answer.
        let clean = fb.encode_batch(&xs).unwrap();
        assert_eq!(clean, StubVae::new().encode_batch(&xs).unwrap());
        // Call 1: the planned error names the injection.
        let err = fb.encode_batch(&xs).unwrap_err();
        assert!(format!("{err:#}").contains("injected"), "{err:#}");
        // Call 2: a delay still answers correctly.
        assert!(fb.decode_batch(&Matrix::new(1, 2, vec![0.0; 2])).is_ok());
        assert_eq!(ctl.calls(), 3);
        assert_eq!(fb.backend_id(), "stub-v1", "id must delegate for bit-identity");
    }

    #[test]
    fn armed_faults_fire_on_the_next_dispatch_and_are_one_shot() {
        let fb = FaultyBackend::new(StubVae::new(), FaultPlan::new());
        let ctl = fb.control();
        ctl.arm(DispatchFault::Panic);
        assert_eq!(ctl.armed_len(), 1);
        let xs = Matrix::new(1, 4, vec![0.0; 4]);
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _ = fb.encode_batch(&xs);
        }));
        assert!(caught.is_err(), "armed panic must unwind");
        assert_eq!(ctl.armed_len(), 0);
        // The wrapper survives its own injected panic: next call is clean.
        assert!(fb.encode_batch(&xs).is_ok());
        assert_eq!(ctl.calls(), 2);
    }
}
