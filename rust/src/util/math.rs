//! Special functions needed by the distribution codecs.
//!
//! No external math crates are available offline, so we implement the small
//! set we need: `erf`/`erfc`, the standard normal CDF `phi` and its inverse
//! (`probit`, Acklam's algorithm + one Halley refinement), and `lgamma`
//! (Lanczos), from which `log_beta` and the beta-binomial log-PMF follow.
//!
//! Accuracy targets are modest (the codecs quantize to ≤ 2⁻²⁴) but
//! determinism matters: everything here is straight-line f64 arithmetic,
//! identical on every run and platform.

use std::f64::consts::PI;

/// Error function, via the Abramowitz & Stegun 7.1.26-style rational
/// approximation refined to double precision (max abs error ~1.2e-7 for the
/// simple form is not enough, so we use a higher-order expansion).
///
/// This implementation follows W. J. Cody's rational Chebyshev approximation
/// strategy in three ranges, giving ~1e-15 relative accuracy.
pub fn erf(x: f64) -> f64 {
    1.0 - erfc(x)
}

/// Complementary error function (Cody-style, three ranges).
pub fn erfc(x: f64) -> f64 {
    let ax = x.abs();
    let z = ax * ax;
    let r = if ax < 0.5 {
        // erf(x) = x * P(z)/Q(z)
        const P: [f64; 5] = [
            3.209377589138469472562e3,
            3.774852376853020208137e2,
            1.138641541510501556495e2,
            3.161123743870565596947e0,
            1.857777061846031526730e-1,
        ];
        const Q: [f64; 5] = [
            2.844236833439170622273e3,
            1.282616526077372275645e3,
            2.440246379344441733056e2,
            2.360129095234412093499e1,
            1.0,
        ];
        let num = ((((P[4] * z + P[3]) * z + P[2]) * z + P[1]) * z) + P[0];
        let den = ((((Q[4] * z + Q[3]) * z + Q[2]) * z + Q[1]) * z) + Q[0];
        return 1.0 - x * num / den;
    } else if ax < 4.0 {
        const P: [f64; 9] = [
            1.23033935479799725272e3,
            2.05107837782607146532e3,
            1.71204761263407058314e3,
            8.81952221241769090411e2,
            2.98635138197400131132e2,
            6.61191906371416294775e1,
            8.88314979438837594118e0,
            5.64188496988670089180e-1,
            2.15311535474403846343e-8,
        ];
        const Q: [f64; 9] = [
            1.23033935480374942043e3,
            3.43936767414372163696e3,
            4.36261909014324715820e3,
            3.29079923573345962678e3,
            1.62138957456669018874e3,
            5.37181101862009857509e2,
            1.17693950891312499305e2,
            1.57449261107098347253e1,
            1.0,
        ];
        let mut num = P[8];
        let mut den = Q[8];
        for i in (0..8).rev() {
            num = num * ax + P[i];
            den = den * ax + Q[i];
        }
        (-z).exp() * num / den
    } else {
        // ax >= 4
        const P: [f64; 6] = [
            -6.58749161529837803157e-4,
            -1.60837851487422766278e-2,
            -1.25781726111229246204e-1,
            -3.60344899949804439429e-1,
            -3.05326634961232344035e-1,
            -1.63153871373020978498e-2,
        ];
        const Q: [f64; 6] = [
            2.33520497626869185443e-3,
            6.05183413124413191178e-2,
            5.27905102951428412248e-1,
            1.87295284992346047209e0,
            2.56852019228982242072e0,
            1.0,
        ];
        let inv_z = 1.0 / z;
        let mut num = P[5];
        let mut den = Q[5];
        for i in (0..5).rev() {
            num = num * inv_z + P[i];
            den = den * inv_z + Q[i];
        }
        let r = inv_z * num / den;
        let frac = (1.0 / (PI.sqrt()) + r) / ax;
        let e = (-z).exp();
        if e == 0.0 {
            0.0
        } else {
            e * frac
        }
    };
    if x < 0.0 {
        2.0 - r
    } else {
        r
    }
}

/// Standard normal CDF.
#[inline]
pub fn phi(x: f64) -> f64 {
    0.5 * erfc(-x * std::f64::consts::FRAC_1_SQRT_2)
}

/// Standard normal PDF.
#[inline]
pub fn normal_pdf(x: f64) -> f64 {
    (-(x * x) / 2.0).exp() / (2.0 * PI).sqrt()
}

/// Inverse standard normal CDF (probit). Acklam's rational approximation
/// followed by one Halley step, ~1e-15 accuracy over (0, 1).
pub fn probit(p: f64) -> f64 {
    assert!(
        p > 0.0 && p < 1.0,
        "probit domain error: p={p} must be in (0,1)"
    );
    const A: [f64; 6] = [
        -3.969683028665376e+01,
        2.209460984245205e+02,
        -2.759285104469687e+02,
        1.383577518672690e+02,
        -3.066479806614716e+01,
        2.506628277459239e+00,
    ];
    const B: [f64; 5] = [
        -5.447609879822406e+01,
        1.615858368580409e+02,
        -1.556989798598866e+02,
        6.680131188771972e+01,
        -1.328068155288572e+01,
    ];
    const C: [f64; 6] = [
        -7.784894002430293e-03,
        -3.223964580411365e-01,
        -2.400758277161838e+00,
        -2.549732539343734e+00,
        4.374664141464968e+00,
        2.938163982698783e+00,
    ];
    const D: [f64; 4] = [
        7.784695709041462e-03,
        3.224671290700398e-01,
        2.445134137142996e+00,
        3.754408661907416e+00,
    ];
    const P_LOW: f64 = 0.02425;

    let x = if p < P_LOW {
        let q = (-2.0 * p.ln()).sqrt();
        (((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    } else if p <= 1.0 - P_LOW {
        let q = p - 0.5;
        let r = q * q;
        (((((A[0] * r + A[1]) * r + A[2]) * r + A[3]) * r + A[4]) * r + A[5]) * q
            / (((((B[0] * r + B[1]) * r + B[2]) * r + B[3]) * r + B[4]) * r + 1.0)
    } else {
        let q = (-2.0 * (1.0 - p).ln()).sqrt();
        -(((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    };

    // One Halley refinement step.
    let e = phi(x) - p;
    let u = e * (2.0 * PI).sqrt() * (x * x / 2.0).exp();
    x - u / (1.0 + x * u / 2.0)
}

/// Natural log of the gamma function (Lanczos approximation, g=7, n=9).
pub fn lgamma(x: f64) -> f64 {
    const G: [f64; 9] = [
        0.99999999999980993,
        676.5203681218851,
        -1259.1392167224028,
        771.32342877765313,
        -176.61502916214059,
        12.507343278686905,
        -0.13857109526572012,
        9.9843695780195716e-6,
        1.5056327351493116e-7,
    ];
    if x < 0.5 {
        // Reflection formula.
        PI.ln() - (PI * x).sin().abs().ln() - lgamma(1.0 - x)
    } else {
        let x = x - 1.0;
        let mut a = G[0];
        let t = x + 7.5;
        for (i, &g) in G.iter().enumerate().skip(1) {
            a += g / (x + i as f64);
        }
        0.5 * (2.0 * PI).ln() + (x + 0.5) * t.ln() - t + a.ln()
    }
}

/// log B(a, b) = lgamma(a) + lgamma(b) - lgamma(a+b)
#[inline]
pub fn log_beta(a: f64, b: f64) -> f64 {
    lgamma(a) + lgamma(b) - lgamma(a + b)
}

/// log C(n, k)
#[inline]
pub fn log_binomial(n: u32, k: u32) -> f64 {
    lgamma(n as f64 + 1.0) - lgamma(k as f64 + 1.0) - lgamma((n - k) as f64 + 1.0)
}

/// Beta-binomial log-PMF: P(k | n, a, b) = C(n,k) B(k+a, n-k+b) / B(a, b).
/// This mirrors `python/compile/kernels/ref.py::beta_binomial_logpmf`.
pub fn beta_binomial_logpmf(k: u32, n: u32, a: f64, b: f64) -> f64 {
    log_binomial(n, k) + log_beta(k as f64 + a, (n - k) as f64 + b) - log_beta(a, b)
}

/// Numerically-stable log(1 + exp(x)) (softplus), matching jax.nn.softplus.
#[inline]
pub fn softplus(x: f64) -> f64 {
    if x > 30.0 {
        x
    } else if x < -30.0 {
        x.exp()
    } else {
        x.exp().ln_1p()
    }
}

/// Logistic sigmoid.
#[inline]
pub fn sigmoid(x: f64) -> f64 {
    if x >= 0.0 {
        1.0 / (1.0 + (-x).exp())
    } else {
        let e = x.exp();
        e / (1.0 + e)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn erf_reference_values() {
        // Reference values from tables / scipy.
        let cases = [
            (0.0, 0.0),
            (0.5, 0.5204998778130465),
            (1.0, 0.8427007929497149),
            (2.0, 0.9953222650189527),
            (3.0, 0.9999779095030014),
            (-1.0, -0.8427007929497149),
        ];
        for (x, want) in cases {
            let got = erf(x);
            assert!((got - want).abs() < 1e-12, "erf({x}) = {got}, want {want}");
        }
    }

    #[test]
    fn erfc_large_argument() {
        // erfc(5) ≈ 1.5374597944280349e-12
        let got = erfc(5.0);
        assert!((got - 1.5374597944280349e-12).abs() < 1e-24, "{got}");
        // erfc(-5) = 2 - erfc(5): within one ulp of 2.
        assert!((erfc(-5.0) - (2.0 - 1.5374597944280349e-12)).abs() < 1e-15);
    }

    #[test]
    fn phi_symmetry_and_values() {
        assert!((phi(0.0) - 0.5).abs() < 1e-15);
        assert!((phi(1.959963984540054) - 0.975).abs() < 1e-12);
        for x in [-3.0, -1.0, -0.1, 0.7, 2.5] {
            assert!((phi(x) + phi(-x) - 1.0).abs() < 1e-14);
        }
    }

    #[test]
    fn probit_is_inverse_of_phi() {
        for i in 1..1000 {
            let p = i as f64 / 1000.0;
            let x = probit(p);
            assert!((phi(x) - p).abs() < 1e-12, "p={p} x={x} phi={}", phi(x));
        }
        // Extreme tails.
        for p in [1e-12, 1e-9, 1e-6, 1.0 - 1e-6, 1.0 - 1e-9] {
            let x = probit(p);
            assert!(
                (phi(x) - p).abs() / p.min(1.0 - p) < 1e-6,
                "p={p} phi(probit)={}",
                phi(x)
            );
        }
    }

    #[test]
    fn lgamma_reference_values() {
        let cases = [
            (1.0, 0.0),
            (2.0, 0.0),
            (3.0, std::f64::consts::LN_2),
            (0.5, 0.5723649429247001), // ln(sqrt(pi))
            (10.0, 12.801827480081469),
        ];
        for (x, want) in cases {
            let got = lgamma(x);
            assert!(
                (got - want).abs() < 1e-12,
                "lgamma({x}) = {got}, want {want}"
            );
        }
    }

    #[test]
    fn beta_binomial_sums_to_one() {
        for (a, b) in [(1.0, 1.0), (0.5, 0.5), (2.3, 7.7), (20.0, 3.0)] {
            let total: f64 = (0..=255)
                .map(|k| beta_binomial_logpmf(k, 255, a, b).exp())
                .sum();
            assert!((total - 1.0).abs() < 1e-9, "a={a} b={b} total={total}");
        }
    }

    #[test]
    fn beta_binomial_uniform_when_a_b_one() {
        // BetaBin(n, 1, 1) is uniform over 0..=n.
        for k in [0u32, 17, 128, 255] {
            let lp = beta_binomial_logpmf(k, 255, 1.0, 1.0);
            assert!((lp - (1.0f64 / 256.0).ln()).abs() < 1e-10);
        }
    }

    #[test]
    fn sigmoid_softplus_consistency() {
        for x in [-40.0f64, -5.0, -0.3, 0.0, 0.3, 5.0, 40.0] {
            // d/dx softplus = sigmoid; check via finite differences (interior).
            if x.abs() < 20.0 {
                let h = 1e-6;
                let d = (softplus(x + h) - softplus(x - h)) / (2.0 * h);
                assert!((d - sigmoid(x)).abs() < 1e-6);
            }
            // Strict bounds only away from f64 saturation (sigmoid(40)
            // rounds to exactly 1.0 in double precision).
            assert!(sigmoid(x) > 0.0 && sigmoid(x) <= 1.0);
            if x.abs() < 30.0 {
                assert!(sigmoid(x) < 1.0);
            }
        }
    }
}
