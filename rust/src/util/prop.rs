//! A tiny property-based-testing harness (proptest is not available
//! offline). Provides seeded case generation with automatic minimal-ish
//! shrinking for byte-vector inputs, which is what most codec roundtrip
//! properties need.

use crate::util::rng::Rng;

/// Run `prop` on `cases` random byte vectors of length up to `max_len`,
/// drawn from distributions that stress codecs: uniform random, low-entropy
/// (few symbols), runs, and text-like. On failure, shrink to a small
/// counterexample and panic with its debug representation.
pub fn check_bytes(seed: u64, cases: usize, max_len: usize, prop: impl Fn(&[u8]) -> bool) {
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let data = gen_bytes(&mut rng, max_len, case);
        if !prop(&data) {
            let min = shrink_bytes(&data, &prop);
            panic!(
                "property failed (seed={seed}, case={case}); minimal counterexample \
                 ({} bytes): {:?}",
                min.len(),
                &min[..min.len().min(64)]
            );
        }
    }
}

/// Generate a byte vector from one of several codec-stressing families.
pub fn gen_bytes(rng: &mut Rng, max_len: usize, case: usize) -> Vec<u8> {
    let len = rng.below(max_len as u64 + 1) as usize;
    match case % 5 {
        // Uniform random (incompressible).
        0 => (0..len).map(|_| rng.next_u32() as u8).collect(),
        // Low-entropy alphabet.
        1 => {
            let k = 1 + rng.below(4) as u8;
            (0..len).map(|_| rng.below(k as u64) as u8).collect()
        }
        // Long runs.
        2 => {
            let mut v = Vec::with_capacity(len);
            while v.len() < len {
                let b = rng.next_u32() as u8;
                let run = 1 + rng.below(200) as usize;
                for _ in 0..run.min(len - v.len()) {
                    v.push(b);
                }
            }
            v
        }
        // Text-like (skewed printable distribution with repeats).
        3 => {
            let words = [&b"the "[..], b"quick ", b"brown ", b"fox ", b"lazy ", b"dog. "];
            let mut v = Vec::with_capacity(len);
            while v.len() < len {
                let w = words[rng.below(words.len() as u64) as usize];
                v.extend_from_slice(w);
            }
            v.truncate(len);
            v
        }
        // Image-like: smooth gradients with noise (stresses predictors).
        _ => {
            let mut v = Vec::with_capacity(len);
            let mut x = rng.below(256) as i32;
            for _ in 0..len {
                x += rng.below(7) as i32 - 3;
                x = x.clamp(0, 255);
                v.push(x as u8);
            }
            v
        }
    }
}

/// Greedy shrink: try removing chunks, then halving values.
fn shrink_bytes(data: &[u8], prop: &impl Fn(&[u8]) -> bool) -> Vec<u8> {
    let mut cur = data.to_vec();
    // Chunk removal with decreasing chunk sizes.
    let mut chunk = (cur.len() / 2).max(1);
    while chunk >= 1 {
        let mut i = 0;
        while i + chunk <= cur.len() {
            let mut cand = cur.clone();
            cand.drain(i..i + chunk);
            if !prop(&cand) {
                cur = cand;
            } else {
                i += chunk;
            }
        }
        if chunk == 1 {
            break;
        }
        chunk /= 2;
    }
    // Value simplification toward zero.
    for i in 0..cur.len() {
        while cur[i] > 0 {
            let mut cand = cur.clone();
            cand[i] /= 2;
            if !prop(&cand) {
                cur = cand;
            } else {
                break;
            }
        }
    }
    cur
}

/// Run `prop` on `cases` random `(u64)` seeds — a generic scalar property
/// runner for numeric invariants.
pub fn check_u64(seed: u64, cases: usize, prop: impl Fn(u64) -> bool) {
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let x = rng.next_u64();
        assert!(prop(x), "property failed (seed={seed}, case={case}, x={x})");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check_bytes(1, 50, 300, |_d| true);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_reports() {
        check_bytes(2, 50, 300, |d| d.len() < 10);
    }

    #[test]
    fn shrink_finds_small_counterexample() {
        // Property: no byte equals 200. Generator family 0 will hit it.
        let caught = std::panic::catch_unwind(|| {
            check_bytes(3, 200, 400, |d| !d.contains(&200));
        });
        assert!(caught.is_err());
    }

    #[test]
    fn generators_cover_all_families() {
        let mut rng = Rng::new(9);
        for case in 0..5 {
            let v = gen_bytes(&mut rng, 100, case);
            assert!(v.len() <= 100);
        }
    }
}
