//! A tiny property-based-testing harness (proptest is not available
//! offline). Provides seeded case generation with automatic minimal-ish
//! shrinking for byte-vector inputs (what most codec roundtrip properties
//! need), plus generators for quantized symbol intervals and coder
//! configurations used to fuzz the [`crate::ans::EntropyCoder`]
//! implementations against each other.

use crate::ans::Interval;
use crate::util::rng::Rng;

/// Run `prop` on `cases` random byte vectors of length up to `max_len`,
/// drawn from distributions that stress codecs: uniform random, low-entropy
/// (few symbols), runs, and text-like. On failure, shrink to a small
/// counterexample and panic with its debug representation.
pub fn check_bytes(seed: u64, cases: usize, max_len: usize, prop: impl Fn(&[u8]) -> bool) {
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let data = gen_bytes(&mut rng, max_len, case);
        if !prop(&data) {
            let min = shrink_bytes(&data, &prop);
            panic!(
                "property failed (seed={seed}, case={case}); minimal counterexample \
                 ({} bytes): {:?}",
                min.len(),
                &min[..min.len().min(64)]
            );
        }
    }
}

/// Generate a byte vector from one of several codec-stressing families.
pub fn gen_bytes(rng: &mut Rng, max_len: usize, case: usize) -> Vec<u8> {
    let len = rng.below(max_len as u64 + 1) as usize;
    match case % 5 {
        // Uniform random (incompressible).
        0 => (0..len).map(|_| rng.next_u32() as u8).collect(),
        // Low-entropy alphabet.
        1 => {
            let k = 1 + rng.below(4) as u8;
            (0..len).map(|_| rng.below(k as u64) as u8).collect()
        }
        // Long runs.
        2 => {
            let mut v = Vec::with_capacity(len);
            while v.len() < len {
                let b = rng.next_u32() as u8;
                let run = 1 + rng.below(200) as usize;
                for _ in 0..run.min(len - v.len()) {
                    v.push(b);
                }
            }
            v
        }
        // Text-like (skewed printable distribution with repeats).
        3 => {
            let words = [&b"the "[..], b"quick ", b"brown ", b"fox ", b"lazy ", b"dog. "];
            let mut v = Vec::with_capacity(len);
            while v.len() < len {
                let w = words[rng.below(words.len() as u64) as usize];
                v.extend_from_slice(w);
            }
            v.truncate(len);
            v
        }
        // Image-like: smooth gradients with noise (stresses predictors).
        _ => {
            let mut v = Vec::with_capacity(len);
            let mut x = rng.below(256) as i32;
            for _ in 0..len {
                x += rng.below(7) as i32 - 3;
                x = x.clamp(0, 255);
                v.push(x as u8);
            }
            v
        }
    }
}

/// Greedy shrink: try removing chunks, then halving values.
fn shrink_bytes(data: &[u8], prop: &impl Fn(&[u8]) -> bool) -> Vec<u8> {
    let mut cur = data.to_vec();
    // Chunk removal with decreasing chunk sizes.
    let mut chunk = (cur.len() / 2).max(1);
    while chunk >= 1 {
        let mut i = 0;
        while i + chunk <= cur.len() {
            let mut cand = cur.clone();
            cand.drain(i..i + chunk);
            if !prop(&cand) {
                cur = cand;
            } else {
                i += chunk;
            }
        }
        if chunk == 1 {
            break;
        }
        chunk /= 2;
    }
    // Value simplification toward zero.
    for i in 0..cur.len() {
        while cur[i] > 0 {
            let mut cand = cur.clone();
            cand[i] /= 2;
            if !prop(&cand) {
                cur = cand;
            } else {
                break;
            }
        }
    }
    cur
}

/// Run `prop` on `cases` random `(u64)` seeds — a generic scalar property
/// runner for numeric invariants.
pub fn check_u64(seed: u64, cases: usize, prop: impl Fn(u64) -> bool) {
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let x = rng.next_u64();
        assert!(prop(x), "property failed (seed={seed}, case={case}, x={x})");
    }
}

/// A random entropy-coder configuration: coding precision, alphabet size
/// and symbol-sequence length, drawn from ranges that stress both the
/// stack and the interleaved coder (tiny alphabets, near-maximal
/// precision, lengths around lane-count boundaries).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CoderConfig {
    /// Quantization precision; intervals tile `[0, 2^prec)`.
    pub prec: u32,
    /// Alphabet size (`2 ≤ n_syms < 2^prec`).
    pub n_syms: usize,
    /// Number of symbols to code.
    pub len: usize,
}

/// Draw a [`CoderConfig`]. `case` cycles length families so lane-count
/// edge cases (`len % N ≠ 0`, empty, single-symbol) always appear.
pub fn gen_coder_config(rng: &mut Rng, case: usize) -> CoderConfig {
    let prec = 8 + rng.below(17) as u32; // 8..=24
    let max_syms = ((1u64 << prec) / 4).min(300) as usize;
    let n_syms = 2 + rng.below(max_syms as u64 - 1) as usize;
    let len = match case % 4 {
        0 => rng.below(8) as usize,              // tiny (incl. empty)
        1 => 1 + rng.below(64) as usize,         // around lane boundaries
        2 => 256 + rng.below(1024) as usize,     // medium
        _ => 2048 + rng.below(4096) as usize,    // long chains
    };
    CoderConfig { prec, n_syms, len }
}

/// Like [`gen_coder_config`] but over the *full* supported precision
/// range (2..=32), with shorter sequences — used to pin the prepared
/// (division-free) encode path to the division path at the extremes,
/// where the reciprocal and renormalization-threshold arithmetic is most
/// delicate.
pub fn gen_coder_config_wide(rng: &mut Rng, case: usize) -> CoderConfig {
    let prec = 2 + rng.below(31) as u32; // 2..=32
    let max_syms = ((1u64 << prec) - 1).min(300) as usize;
    let n_syms = 2 + rng.below(max_syms as u64 - 1) as usize;
    let len = match case % 3 {
        0 => rng.below(8) as usize,
        1 => 1 + rng.below(128) as usize,
        _ => 512 + rng.below(1536) as usize,
    };
    CoderConfig { prec, n_syms, len }
}

/// Generate a quantized interval table for `cfg.n_syms` symbols tiling
/// `[0, 2^prec)` exactly, with every frequency ≥ 1 (the invariant the
/// quantizer guarantees and the coders rely on). Weight families mirror
/// [`gen_bytes`]: uniform, geometric (skewed), and spiked.
pub fn gen_intervals(rng: &mut Rng, cfg: &CoderConfig) -> Vec<Interval> {
    let k = cfg.n_syms;
    let total = 1u64 << cfg.prec;
    let weights: Vec<f64> = match rng.below(3) {
        0 => (0..k).map(|_| 1.0).collect(),
        1 => (0..k).map(|i| 0.7f64.powi((i % 40) as i32)).collect(),
        _ => {
            let spike = rng.below(k as u64) as usize;
            (0..k).map(|i| if i == spike { 1e6 } else { 1.0 }).collect()
        }
    };
    let wsum: f64 = weights.iter().sum();
    // Strictly-monotone CDF map (same construction as QuantizedCdf).
    let mut cdf = Vec::with_capacity(k + 1);
    cdf.push(0u64);
    let scale = (total - k as u64) as f64 / wsum;
    let mut acc = 0.0;
    for (i, &w) in weights.iter().enumerate() {
        acc += w;
        let g = if i + 1 == k {
            total
        } else {
            ((acc * scale).round() as u64 + i as u64 + 1).min(total)
        };
        cdf.push(g);
    }
    (0..k)
        .map(|i| Interval {
            start: cdf[i] as u32,
            freq: (cdf[i + 1] - cdf[i]) as u32,
        })
        .collect()
}

/// Run `prop` over `cases` random coder configs. For each case the
/// property receives the config, the interval table, and a random symbol
/// sequence of length `cfg.len`.
pub fn check_coders(
    seed: u64,
    cases: usize,
    prop: impl Fn(&CoderConfig, &[Interval], &[usize]) -> bool,
) {
    check_coders_with(seed, cases, gen_coder_config, prop)
}

/// [`check_coders`] over the full precision range (2..=32) via
/// [`gen_coder_config_wide`].
pub fn check_coders_wide(
    seed: u64,
    cases: usize,
    prop: impl Fn(&CoderConfig, &[Interval], &[usize]) -> bool,
) {
    check_coders_with(seed, cases, gen_coder_config_wide, prop)
}

fn check_coders_with(
    seed: u64,
    cases: usize,
    gen: impl Fn(&mut Rng, usize) -> CoderConfig,
    prop: impl Fn(&CoderConfig, &[Interval], &[usize]) -> bool,
) {
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let cfg = gen(&mut rng, case);
        let intervals = gen_intervals(&mut rng, &cfg);
        let syms: Vec<usize> = (0..cfg.len)
            .map(|_| rng.below(cfg.n_syms as u64) as usize)
            .collect();
        assert!(
            prop(&cfg, &intervals, &syms),
            "coder property failed (seed={seed}, case={case}, cfg={cfg:?})"
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check_bytes(1, 50, 300, |_d| true);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_reports() {
        check_bytes(2, 50, 300, |d| d.len() < 10);
    }

    #[test]
    fn shrink_finds_small_counterexample() {
        // Property: no byte equals 200. Generator family 0 will hit it.
        let caught = std::panic::catch_unwind(|| {
            check_bytes(3, 200, 400, |d| !d.contains(&200));
        });
        assert!(caught.is_err());
    }

    #[test]
    fn generators_cover_all_families() {
        let mut rng = Rng::new(9);
        for case in 0..5 {
            let v = gen_bytes(&mut rng, 100, case);
            assert!(v.len() <= 100);
        }
    }

    #[test]
    fn interval_generator_tiles_exactly_with_nonzero_freqs() {
        let mut rng = Rng::new(31);
        for case in 0..200 {
            let cfg = gen_coder_config(&mut rng, case);
            let ivs = gen_intervals(&mut rng, &cfg);
            assert_eq!(ivs.len(), cfg.n_syms);
            let mut pos = 0u64;
            for iv in &ivs {
                assert_eq!(iv.start as u64, pos, "intervals must tile ({cfg:?})");
                assert!(iv.freq >= 1, "zero-frequency symbol ({cfg:?})");
                pos += iv.freq as u64;
            }
            assert_eq!(pos, 1u64 << cfg.prec, "mass must sum to 2^prec ({cfg:?})");
        }
    }

    #[test]
    fn coder_config_hits_all_length_families() {
        let mut rng = Rng::new(32);
        let mut saw_empty = false;
        let mut saw_long = false;
        for case in 0..64 {
            let cfg = gen_coder_config(&mut rng, case);
            assert!((8..=24).contains(&cfg.prec));
            assert!(cfg.n_syms >= 2 && (cfg.n_syms as u64) < (1u64 << cfg.prec));
            saw_empty |= cfg.len == 0;
            saw_long |= cfg.len >= 2048;
        }
        assert!(saw_long, "long-chain family never drawn");
        let _ = saw_empty; // empty is probabilistic; long is guaranteed by case % 4
    }

    #[test]
    fn check_coders_runs_properties() {
        check_coders(33, 20, |cfg, ivs, syms| {
            syms.len() == cfg.len && ivs.len() == cfg.n_syms
        });
    }

    #[test]
    fn wide_config_covers_extreme_precisions_with_valid_tables() {
        let mut rng = Rng::new(34);
        let mut lo = u32::MAX;
        let mut hi = 0;
        for case in 0..300 {
            let cfg = gen_coder_config_wide(&mut rng, case);
            assert!((2..=32).contains(&cfg.prec));
            assert!(cfg.n_syms >= 2 && (cfg.n_syms as u64) < (1u64 << cfg.prec));
            lo = lo.min(cfg.prec);
            hi = hi.max(cfg.prec);
            let ivs = gen_intervals(&mut rng, &cfg);
            let mut pos = 0u64;
            for iv in &ivs {
                assert_eq!(iv.start as u64, pos, "{cfg:?}");
                assert!(iv.freq >= 1, "{cfg:?}");
                pos += iv.freq as u64;
            }
            assert_eq!(pos, 1u64 << cfg.prec, "{cfg:?}");
        }
        assert!(lo <= 4, "low precisions never drawn (min {lo})");
        assert!(hi >= 30, "high precisions never drawn (max {hi})");
    }
}
