//! Timing helpers shared by the bench harness and the coordinator metrics.

use std::time::{Duration, Instant};

/// A simple scoped stopwatch.
#[derive(Debug)]
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Self {
            start: Instant::now(),
        }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }
}

/// Online mean/min/max/stddev accumulator (Welford).
#[derive(Debug, Clone, Default)]
pub struct Stats {
    pub n: u64,
    mean: f64,
    m2: f64,
    pub min: f64,
    pub max: f64,
}

impl Stats {
    pub fn new() -> Self {
        Self {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        if x < self.min {
            self.min = x;
        }
        if x > self.max {
            self.max = x;
        }
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn stddev(&self) -> f64 {
        self.var().sqrt()
    }
}

/// Human-friendly duration formatting for reports.
pub fn fmt_duration(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s < 1e-6 {
        format!("{:.1} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2} ms", s * 1e3)
    } else {
        format!("{:.2} s", s)
    }
}

/// Human-friendly byte counts.
pub fn fmt_bytes(n: usize) -> String {
    if n < 1024 {
        format!("{n} B")
    } else if n < 1024 * 1024 {
        format!("{:.1} KiB", n as f64 / 1024.0)
    } else {
        format!("{:.2} MiB", n as f64 / (1024.0 * 1024.0))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_known_values() {
        let mut s = Stats::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.push(x);
        }
        assert_eq!(s.n, 8);
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert!((s.var() - 32.0 / 7.0).abs() < 1e-12);
        assert_eq!(s.min, 2.0);
        assert_eq!(s.max, 9.0);
    }

    #[test]
    fn duration_formatting() {
        assert_eq!(fmt_duration(Duration::from_nanos(500)), "500.0 ns");
        assert_eq!(fmt_duration(Duration::from_micros(42)), "42.00 µs");
        assert_eq!(fmt_duration(Duration::from_millis(3)), "3.00 ms");
        assert_eq!(fmt_duration(Duration::from_secs(2)), "2.00 s");
    }

    #[test]
    fn byte_formatting() {
        assert_eq!(fmt_bytes(12), "12 B");
        assert_eq!(fmt_bytes(2048), "2.0 KiB");
        assert_eq!(fmt_bytes(3 * 1024 * 1024), "3.00 MiB");
    }
}
