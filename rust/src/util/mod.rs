//! Foundation utilities: PRNG, special functions, bit I/O, JSON, timing,
//! and a tiny property-testing harness. These replace the crates (rand,
//! serde, proptest, criterion) that are unavailable in this offline build.

pub mod bitio;
pub mod crc32;
pub mod json;
pub mod math;
pub mod prop;
pub mod rng;
pub mod timer;
