//! Foundation utilities: PRNG, special functions, bit I/O, JSON, timing,
//! and a tiny property-testing harness. These replace the crates (rand,
//! serde, proptest, criterion) that are unavailable in this offline build.

pub mod bitio;
pub mod crc32;
pub mod fault;
pub mod json;
pub mod math;
pub mod prop;
pub mod rng;
pub mod timer;

/// Deterministic near-even partition of `n` items into at most `k`
/// non-empty contiguous ranges (the first `n % k` ranges get one extra
/// item). The split depends only on `(n, k)` — never on thread
/// scheduling — which is what makes chunked containers reproducible and
/// row-sharded NN dispatches bitwise-stitchable. ONE implementation on
/// purpose: the bbans chunked-coding paths and the model-layer batch
/// sharding must agree on the same split semantics.
pub fn chunk_ranges(n: usize, k: usize) -> Vec<std::ops::Range<usize>> {
    let k = k.clamp(1, n.max(1));
    let base = n / k;
    let rem = n % k;
    let mut out = Vec::with_capacity(k);
    let mut start = 0;
    for i in 0..k {
        let len = base + usize::from(i < rem);
        out.push(start..start + len);
        start += len;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunk_ranges_tile_exactly_and_clamp() {
        for (n, k) in [(0usize, 3usize), (1, 1), (5, 2), (7, 7), (7, 50), (100, 3)] {
            let r = chunk_ranges(n, k);
            assert!(!r.is_empty());
            assert!(r.len() <= k.max(1));
            assert_eq!(r.first().unwrap().start, 0);
            assert_eq!(r.last().unwrap().end, n);
            for w in r.windows(2) {
                assert_eq!(w[0].end, w[1].start, "ranges must tile");
            }
            if n > 0 {
                assert!(r.iter().all(|x| !x.is_empty()), "n={n} k={k}: empty range");
            }
        }
    }
}
