//! Small, fast, deterministic PRNGs.
//!
//! This crate cannot depend on the `rand` ecosystem (offline build), and for
//! BB-ANS we *want* full determinism across runs and platforms: the initial
//! "clean bits" of a chain are derived from a seed recorded in the container
//! header, so the decoder can verify them. SplitMix64 seeds Xoshiro256++,
//! the generator used everywhere in the crate (tests, synthetic data,
//! clean-bit supplies, benchmarks).

/// SplitMix64 — used to expand a single `u64` seed into generator state.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9e3779b97f4a7c15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
        z ^ (z >> 31)
    }
}

/// Xoshiro256++ — the workhorse generator.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed (expanded via SplitMix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let mut s = [0u64; 4];
        for v in s.iter_mut() {
            *v = sm.next_u64();
        }
        // Avoid the (astronomically unlikely) all-zero state.
        if s == [0, 0, 0, 0] {
            s[0] = 1;
        }
        Self { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, n)` without modulo bias (Lemire's method).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform `f64` in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform `f32` in `[0, 1)`.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Standard normal via Box–Muller (cached second value dropped for
    /// simplicity; this is not a hot path).
    pub fn normal(&mut self) -> f64 {
        let u1 = loop {
            let u = self.f64();
            if u > 0.0 {
                break u;
            }
        };
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// A fresh buffer of uniformly random `u32` words ("clean bits").
    pub fn words(&mut self, n: usize) -> Vec<u32> {
        (0..n).map(|_| self.next_u32()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(9);
        for _ in 0..1000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn normal_moments_roughly_standard() {
        let mut r = Rng::new(11);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean={mean}");
        assert!((var - 1.0).abs() < 0.08, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(3);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }
}
