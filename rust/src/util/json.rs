//! Minimal JSON parser/serializer (serde is not available offline).
//!
//! Supports the full JSON grammar except `\u` surrogate pairs are combined
//! but exotic numbers (hex, infinities) are rejected, as per spec. Used for
//! `artifacts/model_config.json` and the server protocol's control frames.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            b: s.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as u64),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|v| v as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Fetch a required key, with a readable error.
    pub fn req(&self, key: &str) -> Result<&Json, JsonError> {
        self.get(key).ok_or_else(|| JsonError {
            pos: 0,
            msg: format!("missing key '{key}'"),
        })
    }

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(
            pairs
                .into_iter()
                .map(|(k, v)| (k.to_string(), v))
                .collect(),
        )
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9.0e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\r' => out.push_str("\\r"),
                        '\t' => out.push_str("\\t"),
                        c if (c as u32) < 0x20 => {
                            out.push_str(&format!("\\u{:04x}", c as u32));
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Compact serialization (callers use the blanket `ToString`).
impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        self.write(&mut s);
        f.write_str(&s)
    }
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn skip_ws(&mut self) {
        while self.pos < self.b.len() && matches!(self.b[self.pos], b' ' | b'\t' | b'\n' | b'\r') {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek().ok_or_else(|| self.err("unexpected eof"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => Err(self.err(&format!("unexpected byte 0x{c:02x}"))),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.skip_ws();
            a.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek().ok_or_else(|| self.err("eof in string"))?;
            self.pos += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek().ok_or_else(|| self.err("eof in escape"))?;
                    self.pos += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            let cp = self.hex4()?;
                            if (0xD800..0xDC00).contains(&cp) {
                                // surrogate pair
                                self.expect(b'\\')?;
                                self.expect(b'u')?;
                                let lo = self.hex4()?;
                                if !(0xDC00..0xE000).contains(&lo) {
                                    return Err(self.err("invalid low surrogate"));
                                }
                                let c =
                                    0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                s.push(
                                    char::from_u32(c)
                                        .ok_or_else(|| self.err("bad codepoint"))?,
                                );
                            } else {
                                s.push(
                                    char::from_u32(cp)
                                        .ok_or_else(|| self.err("bad codepoint"))?,
                                );
                            }
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                }
                c if c < 0x20 => return Err(self.err("control char in string")),
                c if c < 0x80 => s.push(c as char),
                _ => {
                    // Multi-byte UTF-8: find the full char from the source.
                    let start = self.pos - 1;
                    let rest = &self.b[start..];
                    let st = std::str::from_utf8(&rest[..rest.len().min(4)])
                        .or_else(|e| {
                            if e.valid_up_to() > 0 {
                                std::str::from_utf8(&rest[..e.valid_up_to()])
                            } else {
                                Err(e)
                            }
                        })
                        .map_err(|_| self.err("invalid utf8"))?;
                    let ch = st.chars().next().ok_or_else(|| self.err("invalid utf8"))?;
                    s.push(ch);
                    self.pos = start + ch.len_utf8();
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        if self.pos + 4 > self.b.len() {
            return Err(self.err("eof in \\u escape"));
        }
        let hs = std::str::from_utf8(&self.b[self.pos..self.pos + 4])
            .map_err(|_| self.err("bad hex"))?;
        let v = u32::from_str_radix(hs, 16).map_err(|_| self.err("bad hex"))?;
        self.pos += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let txt = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_nested() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": {"c": "hi\nthere", "d": null}, "e": true}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[1].as_f64(), Some(2.5));
        assert_eq!(v.get("e").unwrap().as_bool(), Some(true));
        let ser = v.to_string();
        let v2 = Json::parse(&ser).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn unicode_escapes() {
        let v = Json::parse(r#""é😀x""#).unwrap();
        assert_eq!(v.as_str(), Some("é😀x"));
        // Raw multibyte UTF-8 passthrough.
        let v = Json::parse("\"héllo 漢字\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo 漢字"));
    }

    #[test]
    fn rejects_garbage() {
        for bad in ["{", "[1,]", "{\"a\":}", "tru", "1.2.3", "\"\\q\"", "[1] x"] {
            assert!(Json::parse(bad).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn integers_serialize_without_dot() {
        let v = Json::Num(42.0);
        assert_eq!(v.to_string(), "42");
        assert_eq!(Json::Num(0.5).to_string(), "0.5");
    }

    #[test]
    fn obj_builder_and_req() {
        let v = Json::obj(vec![("x", Json::Num(3.0)), ("s", Json::Str("y".into()))]);
        assert_eq!(v.req("x").unwrap().as_u64(), Some(3));
        assert!(v.req("zzz").is_err());
    }
}
