//! Bit-level I/O used by the baseline codecs (DEFLATE, bz-style, WebP-style).
//!
//! Two bit orders are needed:
//! * **LSB-first** (DEFLATE): bits are packed into each byte starting at the
//!   least-significant bit. Huffman codes in DEFLATE are additionally stored
//!   most-significant-code-bit first, which callers handle by reversing the
//!   code (see `huffman::reverse_bits`).
//! * **MSB-first** (our bz-style container): straight big-endian bit packing.

/// LSB-first bit writer (DEFLATE convention).
#[derive(Debug, Default)]
pub struct LsbWriter {
    out: Vec<u8>,
    bitbuf: u64,
    nbits: u32,
}

impl LsbWriter {
    pub fn new() -> Self {
        Self::default()
    }

    /// Write the low `n` bits of `v` (n ≤ 57).
    #[inline]
    pub fn write_bits(&mut self, v: u64, n: u32) {
        debug_assert!(n <= 57);
        debug_assert!(n == 64 || v < (1u64 << n));
        self.bitbuf |= v << self.nbits;
        self.nbits += n;
        while self.nbits >= 8 {
            self.out.push((self.bitbuf & 0xff) as u8);
            self.bitbuf >>= 8;
            self.nbits -= 8;
        }
    }

    /// Pad to a byte boundary with zero bits.
    pub fn align_byte(&mut self) {
        if self.nbits > 0 {
            self.out.push((self.bitbuf & 0xff) as u8);
            self.bitbuf = 0;
            self.nbits = 0;
        }
    }

    /// Write raw bytes; requires byte alignment.
    pub fn write_bytes(&mut self, bytes: &[u8]) {
        assert_eq!(self.nbits, 0, "write_bytes requires byte alignment");
        self.out.extend_from_slice(bytes);
    }

    pub fn bit_len(&self) -> usize {
        self.out.len() * 8 + self.nbits as usize
    }

    pub fn finish(mut self) -> Vec<u8> {
        self.align_byte();
        self.out
    }
}

/// LSB-first bit reader (DEFLATE convention).
#[derive(Debug)]
pub struct LsbReader<'a> {
    data: &'a [u8],
    pos: usize, // byte position
    bitbuf: u64,
    nbits: u32,
}

impl<'a> LsbReader<'a> {
    pub fn new(data: &'a [u8]) -> Self {
        Self {
            data,
            pos: 0,
            bitbuf: 0,
            nbits: 0,
        }
    }

    #[inline]
    fn refill(&mut self) {
        while self.nbits <= 56 && self.pos < self.data.len() {
            self.bitbuf |= (self.data[self.pos] as u64) << self.nbits;
            self.pos += 1;
            self.nbits += 8;
        }
    }

    /// Read `n` bits (n ≤ 57). Returns None if the stream is exhausted.
    #[inline]
    pub fn read_bits(&mut self, n: u32) -> Option<u64> {
        debug_assert!(n <= 57);
        if self.nbits < n {
            self.refill();
            if self.nbits < n {
                return None;
            }
        }
        let v = if n == 0 {
            0
        } else {
            self.bitbuf & ((1u64 << n) - 1)
        };
        self.bitbuf >>= n;
        self.nbits -= n;
        Some(v)
    }

    /// Peek up to `n` bits without consuming (may return fewer near EOF,
    /// zero-padded high bits).
    #[inline]
    pub fn peek_bits(&mut self, n: u32) -> u64 {
        debug_assert!(n <= 57);
        self.refill();
        self.bitbuf & ((1u64 << n) - 1)
    }

    /// Consume `n` bits previously peeked.
    #[inline]
    pub fn consume(&mut self, n: u32) {
        debug_assert!(self.nbits >= n);
        self.bitbuf >>= n;
        self.nbits -= n;
    }

    /// Number of whole bits still available.
    pub fn bits_remaining(&self) -> usize {
        (self.data.len() - self.pos) * 8 + self.nbits as usize
    }

    /// Discard buffered bits to realign to the next byte boundary, then
    /// return the remaining byte slice view (used for stored DEFLATE blocks).
    pub fn align_and_rest(&mut self) -> (&'a [u8], usize) {
        // Drop bits to byte boundary.
        let drop = self.nbits % 8;
        self.consume(drop);
        // Bytes still held in bitbuf:
        let buffered = (self.nbits / 8) as usize;
        (self.data, self.pos - buffered)
    }

    /// Skip forward: consume `n` whole bytes starting from a byte-aligned
    /// position produced by `align_and_rest`.
    pub fn seek_to_byte(&mut self, byte_pos: usize) {
        self.pos = byte_pos;
        self.bitbuf = 0;
        self.nbits = 0;
    }
}

/// MSB-first bit writer.
#[derive(Debug, Default)]
pub struct MsbWriter {
    out: Vec<u8>,
    bitbuf: u64,
    nbits: u32,
}

impl MsbWriter {
    pub fn new() -> Self {
        Self::default()
    }

    /// Write the low `n` bits of `v`, most significant first (n ≤ 57).
    #[inline]
    pub fn write_bits(&mut self, v: u64, n: u32) {
        debug_assert!(n <= 57);
        self.bitbuf = (self.bitbuf << n) | (v & if n == 64 { u64::MAX } else { (1 << n) - 1 });
        self.nbits += n;
        while self.nbits >= 8 {
            self.nbits -= 8;
            self.out.push(((self.bitbuf >> self.nbits) & 0xff) as u8);
        }
    }

    pub fn bit_len(&self) -> usize {
        self.out.len() * 8 + self.nbits as usize
    }

    pub fn finish(mut self) -> Vec<u8> {
        if self.nbits > 0 {
            let pad = 8 - self.nbits;
            self.write_bits(0, pad);
        }
        self.out
    }
}

/// MSB-first bit reader.
#[derive(Debug)]
pub struct MsbReader<'a> {
    data: &'a [u8],
    pos: usize,
    bitbuf: u64,
    nbits: u32,
}

impl<'a> MsbReader<'a> {
    pub fn new(data: &'a [u8]) -> Self {
        Self {
            data,
            pos: 0,
            bitbuf: 0,
            nbits: 0,
        }
    }

    #[inline]
    pub fn read_bits(&mut self, n: u32) -> Option<u64> {
        debug_assert!(n <= 57);
        while self.nbits < n {
            if self.pos >= self.data.len() {
                return None;
            }
            self.bitbuf = (self.bitbuf << 8) | self.data[self.pos] as u64;
            self.pos += 1;
            self.nbits += 8;
        }
        self.nbits -= n;
        let v = (self.bitbuf >> self.nbits) & if n == 0 { 0 } else { (1 << n) - 1 };
        Some(v)
    }

    pub fn read_bit(&mut self) -> Option<u8> {
        self.read_bits(1).map(|b| b as u8)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn lsb_roundtrip_fixed() {
        let mut w = LsbWriter::new();
        w.write_bits(0b101, 3);
        w.write_bits(0xffff, 16);
        w.write_bits(0, 1);
        w.write_bits(0x1234, 13);
        let bytes = w.finish();
        let mut r = LsbReader::new(&bytes);
        assert_eq!(r.read_bits(3), Some(0b101));
        assert_eq!(r.read_bits(16), Some(0xffff));
        assert_eq!(r.read_bits(1), Some(0));
        assert_eq!(r.read_bits(13), Some(0x1234));
    }

    #[test]
    fn lsb_roundtrip_random() {
        let mut rng = Rng::new(123);
        let items: Vec<(u64, u32)> = (0..5000)
            .map(|_| {
                let n = 1 + rng.below(24) as u32;
                let v = rng.next_u64() & ((1 << n) - 1);
                (v, n)
            })
            .collect();
        let mut w = LsbWriter::new();
        for &(v, n) in &items {
            w.write_bits(v, n);
        }
        let bytes = w.finish();
        let mut r = LsbReader::new(&bytes);
        for &(v, n) in &items {
            assert_eq!(r.read_bits(n), Some(v));
        }
    }

    #[test]
    fn msb_roundtrip_random() {
        let mut rng = Rng::new(321);
        let items: Vec<(u64, u32)> = (0..5000)
            .map(|_| {
                let n = 1 + rng.below(30) as u32;
                let v = rng.next_u64() & ((1 << n) - 1);
                (v, n)
            })
            .collect();
        let mut w = MsbWriter::new();
        for &(v, n) in &items {
            w.write_bits(v, n);
        }
        let bytes = w.finish();
        let mut r = MsbReader::new(&bytes);
        for &(v, n) in &items {
            assert_eq!(r.read_bits(n), Some(v));
        }
    }

    #[test]
    fn lsb_peek_consume() {
        let mut w = LsbWriter::new();
        w.write_bits(0b110101, 6);
        w.write_bits(0xab, 8);
        let bytes = w.finish();
        let mut r = LsbReader::new(&bytes);
        let p = r.peek_bits(6);
        assert_eq!(p & 0x3f, 0b110101);
        r.consume(6);
        assert_eq!(r.read_bits(8), Some(0xab));
    }

    #[test]
    fn eof_returns_none() {
        let bytes = [0xffu8];
        let mut r = LsbReader::new(&bytes);
        assert_eq!(r.read_bits(8), Some(0xff));
        assert_eq!(r.read_bits(1), None);
        let mut r2 = MsbReader::new(&bytes);
        assert_eq!(r2.read_bits(4), Some(0xf));
        assert_eq!(r2.read_bits(5), None);
    }
}
