//! CRC-32 (IEEE 802.3, reflected, polynomial 0xEDB88320) from scratch —
//! the checksum used by gzip (RFC 1952) and PNG chunks. Replaces the
//! `crc32fast` crate, which is unavailable in this offline build. The
//! API mirrors the subset of `crc32fast` the baselines use (`hash`, and
//! a streaming `Hasher` with `update`/`finalize`).

const fn make_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 {
                0xEDB8_8320 ^ (c >> 1)
            } else {
                c >> 1
            };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

const TABLE: [u32; 256] = make_table();

/// Streaming CRC-32 (same call shape as `crc32fast::Hasher`).
#[derive(Debug, Clone)]
pub struct Hasher {
    state: u32,
}

impl Hasher {
    pub fn new() -> Self {
        Self { state: !0 }
    }

    #[inline]
    pub fn update(&mut self, data: &[u8]) {
        let mut c = self.state;
        for &b in data {
            c = TABLE[((c ^ b as u32) & 0xff) as usize] ^ (c >> 8);
        }
        self.state = c;
    }

    #[inline]
    pub fn finalize(self) -> u32 {
        !self.state
    }
}

impl Default for Hasher {
    fn default() -> Self {
        Self::new()
    }
}

/// One-shot CRC-32 of `data`.
pub fn hash(data: &[u8]) -> u32 {
    let mut h = Hasher::new();
    h.update(data);
    h.finalize()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reference_vectors() {
        // Canonical check value for CRC-32/IEEE.
        assert_eq!(hash(b"123456789"), 0xCBF4_3926);
        assert_eq!(hash(b""), 0);
        // PNG spec: CRC of "IEND" chunk type with empty body.
        assert_eq!(hash(b"IEND"), 0xAE42_6082);
    }

    #[test]
    fn streaming_matches_oneshot() {
        let data: Vec<u8> = (0..1000u32).map(|i| (i * 31 % 251) as u8).collect();
        let mut h = Hasher::new();
        for chunk in data.chunks(37) {
            h.update(chunk);
        }
        assert_eq!(h.finalize(), hash(&data));
    }
}
