//! Rust-side synthetic image generators, for tests and benches that must
//! run without the Python-generated artifact data.
//!
//! `digits` draws crude digit-like glyphs (strokes on a grid); `natural`
//! produces value-noise images that stand in for the ImageNet64 benchmark
//! data of Table 3 (smooth regions + edges — the statistics the baseline
//! codecs' predictors care about).

use super::Dataset;
use crate::util::rng::Rng;

/// Crude digit-like 28x28 images: random strokes with MNIST-ish sparsity.
pub fn digits(n: usize, seed: u64) -> Dataset {
    let (rows, cols) = (28usize, 28usize);
    let mut rng = Rng::new(seed);
    let images = (0..n)
        .map(|_| {
            let mut img = vec![0u8; rows * cols];
            let strokes = 2 + rng.below(3) as usize;
            for _ in 0..strokes {
                // Random quadratic-ish stroke: walk with momentum.
                let mut x = 6.0 + rng.f64() * 16.0;
                let mut y = 6.0 + rng.f64() * 16.0;
                let mut dx = rng.f64() * 2.0 - 1.0;
                let mut dy = rng.f64() * 2.0 - 1.0;
                let steps = 10 + rng.below(20) as usize;
                for _ in 0..steps {
                    dx += rng.f64() * 0.6 - 0.3;
                    dy += rng.f64() * 0.6 - 0.3;
                    let norm = (dx * dx + dy * dy).sqrt().max(0.3);
                    x += dx / norm;
                    y += dy / norm;
                    let (xi, yi) = (x as i64, y as i64);
                    for oy in -1..=1i64 {
                        for ox in -1..=1i64 {
                            let (px, py) = (xi + ox, yi + oy);
                            if (0..cols as i64).contains(&px) && (0..rows as i64).contains(&py) {
                                let d2 = (ox * ox + oy * oy) as f64;
                                let v = (230.0 * (-d2 * 0.7).exp()) as u8;
                                let idx = py as usize * cols + px as usize;
                                img[idx] = img[idx].max(v);
                            }
                        }
                    }
                }
            }
            img
        })
        .collect();
    Dataset { rows, cols, images }
}

/// Stochastic binarization (pixel ~ Bernoulli(v/255)) with a fixed seed.
pub fn binarize(ds: &Dataset, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed);
    Dataset {
        rows: ds.rows,
        cols: ds.cols,
        images: ds
            .images
            .iter()
            .map(|img| {
                img.iter()
                    .map(|&v| (rng.f64() < v as f64 / 255.0) as u8)
                    .collect()
            })
            .collect(),
    }
}

/// Octave value-noise "natural" images of size `side` × `side` (Table 3's
/// ImageNet64 stand-in; see DESIGN.md §5).
pub fn natural(n: usize, side: usize, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed);
    let images = (0..n)
        .map(|_| {
            let mut img = vec![0f64; side * side];
            // Octaves of bilinear value noise.
            let mut amp = 1.0;
            let mut cell = side / 2;
            while cell >= 1 {
                let gw = side / cell + 2;
                let grid: Vec<f64> = (0..gw * gw).map(|_| rng.f64()).collect();
                for y in 0..side {
                    for x in 0..side {
                        let gx = x as f64 / cell as f64;
                        let gy = y as f64 / cell as f64;
                        let (x0, y0) = (gx as usize, gy as usize);
                        let (fx, fy) = (gx - x0 as f64, gy - y0 as f64);
                        let v00 = grid[y0 * gw + x0];
                        let v01 = grid[y0 * gw + x0 + 1];
                        let v10 = grid[(y0 + 1) * gw + x0];
                        let v11 = grid[(y0 + 1) * gw + x0 + 1];
                        let v = v00 * (1.0 - fx) * (1.0 - fy)
                            + v01 * fx * (1.0 - fy)
                            + v10 * (1.0 - fx) * fy
                            + v11 * fx * fy;
                        img[y * side + x] += amp * v;
                    }
                }
                amp *= 0.55;
                cell /= 2;
            }
            // Occasional hard edge (objects).
            if rng.f64() < 0.8 {
                let edge_x = rng.below(side as u64) as usize;
                let delta = rng.f64() * 0.8 - 0.4;
                for y in 0..side {
                    for x in edge_x..side {
                        img[y * side + x] += delta;
                    }
                }
            }
            let lo = img.iter().cloned().fold(f64::INFINITY, f64::min);
            let hi = img.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            img.iter()
                .map(|v| (255.0 * (v - lo) / (hi - lo + 1e-12)) as u8)
                .collect()
        })
        .collect();
    Dataset {
        rows: side,
        cols: side,
        images,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn digits_are_sparse_and_deterministic() {
        let a = digits(10, 42);
        let b = digits(10, 42);
        assert_eq!(a.images, b.images);
        let nonzero: usize = a.images.iter().flatten().filter(|&&v| v > 0).count();
        let frac = nonzero as f64 / a.raw_bytes() as f64;
        assert!(frac > 0.02 && frac < 0.5, "sparsity {frac}");
    }

    #[test]
    fn binarize_is_deterministic_and_binary() {
        let ds = digits(5, 1);
        let b1 = binarize(&ds, 7);
        let b2 = binarize(&ds, 7);
        assert_eq!(b1.images, b2.images);
        assert!(b1.images.iter().flatten().all(|&v| v <= 1));
    }

    #[test]
    fn natural_images_cover_range() {
        let ds = natural(3, 64, 9);
        assert_eq!(ds.rows, 64);
        for img in &ds.images {
            let lo = *img.iter().min().unwrap();
            let hi = *img.iter().max().unwrap();
            assert!(hi > lo + 100, "dynamic range too small: {lo}..{hi}");
        }
    }
}
