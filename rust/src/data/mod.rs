//! Data pipeline: IDX (MNIST-format) loading, binarization, and a small
//! synthetic image generator for artifact-free tests/benches.

pub mod synth;

use anyhow::{bail, Context, Result};
use std::path::Path;

/// A dataset of equally-sized u8 images.
#[derive(Debug, Clone)]
pub struct Dataset {
    pub rows: usize,
    pub cols: usize,
    pub images: Vec<Vec<u8>>,
}

impl Dataset {
    pub fn pixels(&self) -> usize {
        self.rows * self.cols
    }

    pub fn len(&self) -> usize {
        self.images.len()
    }

    pub fn is_empty(&self) -> bool {
        self.images.is_empty()
    }

    /// Total uncompressed payload in bytes.
    pub fn raw_bytes(&self) -> usize {
        self.len() * self.pixels()
    }

    /// Concatenate all pixels (e.g. for whole-dataset baseline codecs).
    pub fn flat(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.raw_bytes());
        for img in &self.images {
            out.extend_from_slice(img);
        }
        out
    }

    pub fn subset(&self, n: usize) -> Dataset {
        Dataset {
            rows: self.rows,
            cols: self.cols,
            images: self.images.iter().take(n).cloned().collect(),
        }
    }
}

/// Parse an IDX image file (magic 0x803): big-endian header + u8 pixels.
pub fn parse_idx_images(bytes: &[u8]) -> Result<Dataset> {
    if bytes.len() < 16 {
        bail!("IDX file too short");
    }
    let magic = u32::from_be_bytes(bytes[0..4].try_into().unwrap());
    if magic != 0x0000_0803 {
        bail!("bad IDX image magic {magic:#x}");
    }
    let n = u32::from_be_bytes(bytes[4..8].try_into().unwrap()) as usize;
    let rows = u32::from_be_bytes(bytes[8..12].try_into().unwrap()) as usize;
    let cols = u32::from_be_bytes(bytes[12..16].try_into().unwrap()) as usize;
    let need = 16 + n * rows * cols;
    if bytes.len() < need {
        bail!("IDX truncated: have {}, need {need}", bytes.len());
    }
    let px = rows * cols;
    let images = (0..n)
        .map(|i| bytes[16 + i * px..16 + (i + 1) * px].to_vec())
        .collect();
    Ok(Dataset { rows, cols, images })
}

pub fn load_idx_images(path: impl AsRef<Path>) -> Result<Dataset> {
    let bytes = std::fs::read(path.as_ref())
        .with_context(|| format!("reading {}", path.as_ref().display()))?;
    parse_idx_images(&bytes)
}

/// Serialize a dataset back to IDX (tests, fixtures).
pub fn write_idx_images(ds: &Dataset) -> Vec<u8> {
    let mut out = Vec::with_capacity(16 + ds.raw_bytes());
    out.extend_from_slice(&0x0000_0803u32.to_be_bytes());
    out.extend_from_slice(&(ds.len() as u32).to_be_bytes());
    out.extend_from_slice(&(ds.rows as u32).to_be_bytes());
    out.extend_from_slice(&(ds.cols as u32).to_be_bytes());
    for img in &ds.images {
        out.extend_from_slice(img);
    }
    out
}

/// Load the named split from the artifact data directory.
/// `which` ∈ {"train", "test"}; `binarized` picks the pre-binarized file.
pub fn load_split(artifact_dir: impl AsRef<Path>, which: &str, binarized: bool) -> Result<Dataset> {
    let name = match (which, binarized) {
        ("train", false) => "train-images-idx3-ubyte",
        ("train", true) => "train-images-bin-idx3-ubyte",
        ("test", false) => "t10k-images-idx3-ubyte",
        ("test", true) => "t10k-images-bin-idx3-ubyte",
        _ => bail!("unknown split '{which}'"),
    };
    load_idx_images(artifact_dir.as_ref().join("data").join(name))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn idx_roundtrip() {
        let ds = Dataset {
            rows: 2,
            cols: 3,
            images: vec![vec![1, 2, 3, 4, 5, 6], vec![9, 8, 7, 6, 5, 4]],
        };
        let bytes = write_idx_images(&ds);
        let ds2 = parse_idx_images(&bytes).unwrap();
        assert_eq!(ds2.rows, 2);
        assert_eq!(ds2.cols, 3);
        assert_eq!(ds2.images, ds.images);
        assert_eq!(ds2.raw_bytes(), 12);
    }

    #[test]
    fn idx_rejects_garbage() {
        assert!(parse_idx_images(&[0u8; 4]).is_err());
        let mut bytes = write_idx_images(&Dataset {
            rows: 1,
            cols: 1,
            images: vec![vec![0]],
        });
        bytes[3] = 0x01; // wrong magic
        assert!(parse_idx_images(&bytes).is_err());
        let good = write_idx_images(&Dataset {
            rows: 2,
            cols: 2,
            images: vec![vec![0; 4]],
        });
        assert!(parse_idx_images(&good[..good.len() - 1]).is_err());
    }

    #[test]
    fn flat_and_subset() {
        let ds = Dataset {
            rows: 1,
            cols: 2,
            images: vec![vec![1, 2], vec![3, 4], vec![5, 6]],
        };
        assert_eq!(ds.flat(), vec![1, 2, 3, 4, 5, 6]);
        let sub = ds.subset(2);
        assert_eq!(sub.len(), 2);
        assert_eq!(sub.flat(), vec![1, 2, 3, 4]);
    }
}
